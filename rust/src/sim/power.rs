//! Activity-based power and EDP model.
//!
//! The paper measures power in silicon (GF12). We reproduce the *model
//! shape*: dynamic power = per-cycle switched energy x clock frequency,
//! plus a static floor. Per-component energies are calibration constants
//! chosen so Table I/II magnitudes land in range (see DESIGN.md §6); every
//! EDP *ratio* the paper claims derives from (frequency, cycles, activity),
//! which this crate computes.

use crate::dfg::ir::{AluOp, Op};
use crate::pnr::RoutedDesign;

use super::dense::Activity;

/// Per-component switched energies (pJ) and static power (mW).
#[derive(Debug, Clone)]
pub struct EnergyModel {
    pub pe_op_pj: f64,
    pub pe_mul_extra_pj: f64,
    pub mem_access_pj: f64,
    pub sb_hop_pj: f64,
    pub reg_write_pj: f64,
    pub io_word_pj: f64,
    pub static_mw: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            pe_op_pj: 1.5,
            pe_mul_extra_pj: 2.5,
            mem_access_pj: 5.0,
            sb_hop_pj: 0.15,
            reg_write_pj: 0.15,
            io_word_pj: 2.0,
            static_mw: 15.0,
        }
    }
}

/// A power estimate at a given clock.
#[derive(Debug, Clone)]
pub struct PowerEstimate {
    pub freq_mhz: f64,
    pub energy_per_cycle_nj: f64,
    pub dynamic_mw: f64,
    pub static_mw: f64,
}

impl PowerEstimate {
    pub fn total_mw(&self) -> f64 {
        self.dynamic_mw + self.static_mw
    }

    /// Energy (mJ) and EDP (mJ*ms) for a runtime in ms.
    pub fn energy_mj(&self, runtime_ms: f64) -> f64 {
        self.total_mw() * runtime_ms * 1e-3
    }

    pub fn edp(&self, runtime_ms: f64) -> f64 {
        self.energy_mj(runtime_ms) * runtime_ms
    }
}

/// Whether a total power draw fits under an optional cap (mW) — the
/// `explore` engine's Capstone-style `--power-cap` feasibility predicate.
/// No cap means always feasible.
pub fn within_cap(total_mw: f64, cap_mw: Option<f64>) -> bool {
    cap_mw.map_or(true, |cap| total_mw <= cap)
}

/// Steady-state per-cycle activity derived from the design structure
/// (every statically scheduled unit fires each cycle).
pub fn steady_state_activity(d: &RoutedDesign) -> Activity {
    let mut a = Activity::default();
    for node in &d.dfg.nodes {
        match &node.op {
            Op::Alu { op, .. } => {
                a.pe_ops += 1;
                if matches!(op, AluOp::Mul | AluOp::Mac) {
                    a.pe_mul_ops += 1;
                }
                if node.input_regs {
                    a.reg_writes += 2;
                }
            }
            Op::Fused { ops } => {
                // One PE, but every member op's logic switches each cycle.
                a.pe_ops += ops.len() as u64;
                a.pe_mul_ops +=
                    ops.iter().filter(|s| matches!(s.op, AluOp::Mul | AluOp::Mac)).count() as u64;
                if node.input_regs {
                    a.reg_writes += 2;
                }
            }
            Op::Sparse(s) => {
                // Sparse units: one op per cycle at full throughput; FIFO
                // write per input.
                use crate::dfg::ir::SparseOp;
                match s {
                    SparseOp::CrdScan { .. } | SparseOp::ValRead { .. } => a.mem_accesses += 1,
                    _ => a.pe_ops += 1,
                }
                a.reg_writes += 2;
            }
            Op::Delay { cycles, .. } if *cycles > 0 => {
                if node.tile_kind() == crate::arch::params::TileKind::Mem {
                    a.mem_accesses += 2;
                } else {
                    a.reg_writes += *cycles as u64;
                }
            }
            Op::Rom { .. } => a.mem_accesses += 1,
            Op::Accum { .. } => {
                a.pe_ops += 1;
                a.pe_mul_ops += 1;
                a.reg_writes += 1;
            }
            Op::Input { .. } | Op::Output { .. } => a.io_words += 1,
            _ => {}
        }
    }
    // Interconnect: every routed hop switches each cycle in steady state.
    for r in &d.routes {
        for p in &r.sink_paths {
            a.sb_hops += p.len() as u64;
        }
    }
    a.reg_writes += d.sb_regs.len() as u64;
    a.reg_writes += d.rf_delay.values().map(|&v| v as u64).sum::<u64>();
    a
}

/// Energy switched in one cycle (nJ) for an activity profile treated as
/// per-cycle counts.
pub fn energy_per_cycle_nj(a: &Activity, m: &EnergyModel) -> f64 {
    let pj = a.pe_ops as f64 * m.pe_op_pj
        + a.pe_mul_ops as f64 * m.pe_mul_extra_pj
        + a.mem_accesses as f64 * m.mem_access_pj
        + a.sb_hops as f64 * m.sb_hop_pj
        + a.reg_writes as f64 * m.reg_write_pj
        + a.io_words as f64 * m.io_word_pj;
    pj * 1e-3
}

/// Estimate power for a design running at `freq_mhz` (steady state).
pub fn estimate(d: &RoutedDesign, freq_mhz: f64, m: &EnergyModel) -> PowerEstimate {
    let act = steady_state_activity(d);
    let e_nj = energy_per_cycle_nj(&act, m);
    PowerEstimate {
        freq_mhz,
        energy_per_cycle_nj: e_nj,
        // nJ * MHz = mW.
        dynamic_mw: e_nj * freq_mhz,
        static_mw: m.static_mw,
    }
}

/// Per-point power query for design-space exploration: like [`estimate`],
/// but accounts for a design compiled as one region and stamped `copies`
/// times across the array (low unrolling duplication, §V-E) — the dynamic
/// power of every electrically identical copy switches concurrently.
pub fn estimate_scaled(
    d: &RoutedDesign,
    freq_mhz: f64,
    copies: usize,
    m: &EnergyModel,
) -> PowerEstimate {
    let mut p = estimate(d, freq_mhz, m);
    p.dynamic_mw *= copies.max(1) as f64;
    p
}

/// Estimate power from measured simulation activity over `cycles`.
pub fn estimate_from_run(
    a: &Activity,
    cycles: u64,
    freq_mhz: f64,
    m: &EnergyModel,
) -> PowerEstimate {
    let per_cycle = Activity {
        pe_ops: a.pe_ops / cycles.max(1),
        pe_mul_ops: a.pe_mul_ops / cycles.max(1),
        mem_accesses: a.mem_accesses / cycles.max(1),
        sb_hops: a.sb_hops / cycles.max(1),
        reg_writes: a.reg_writes / cycles.max(1),
        io_words: a.io_words / cycles.max(1),
    };
    let e_nj = energy_per_cycle_nj(&per_cycle, m);
    PowerEstimate {
        freq_mhz,
        energy_per_cycle_nj: e_nj,
        dynamic_mw: e_nj * freq_mhz,
        static_mw: m.static_mw,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{compile, CompileCtx, PipelineConfig};

    #[test]
    fn power_scales_with_frequency() {
        let ctx = CompileCtx::paper();
        let app = crate::apps::dense::gaussian(64, 64, 2);
        let c = compile(&app, &ctx, &PipelineConfig::compute_only(), 3).unwrap();
        let m = EnergyModel::default();
        let p100 = estimate(&c.design, 100.0, &m);
        let p500 = estimate(&c.design, 500.0, &m);
        assert!(p500.dynamic_mw > p100.dynamic_mw * 4.9);
        assert_eq!(p100.static_mw, p500.static_mw);
    }

    #[test]
    fn pipelined_design_switches_more_per_cycle() {
        let ctx = CompileCtx::paper();
        let app = crate::apps::dense::gaussian(64, 64, 2);
        let unpip = compile(&app, &ctx, &PipelineConfig::none(), 3).unwrap();
        let pip = compile(&app, &ctx, &PipelineConfig::with_postpnr(), 3).unwrap();
        let m = EnergyModel::default();
        let e0 = estimate(&unpip.design, 100.0, &m).energy_per_cycle_nj;
        let e1 = estimate(&pip.design, 100.0, &m).energy_per_cycle_nj;
        assert!(e1 > e0, "registers add switched energy: {e0} vs {e1}");
        // But not catastrophically (same order of magnitude).
        assert!(e1 < e0 * 2.0);
    }

    #[test]
    fn edp_favors_pipelining() {
        // The paper's core claim: pipelining raises power but lowers EDP
        // dramatically because runtime falls with fmax.
        let ctx = CompileCtx::paper();
        let app = crate::apps::dense::gaussian(512, 512, 2);
        let m = EnergyModel::default();
        let unpip = compile(&app, &ctx, &PipelineConfig::none(), 3).unwrap();
        let pip = compile(&app, &ctx, &PipelineConfig::with_postpnr(), 3).unwrap();
        let rt0 = unpip.runtime_ms();
        let rt1 = pip.runtime_ms();
        let edp0 = estimate(&unpip.design, unpip.fmax_mhz(), &m).edp(rt0);
        let edp1 = estimate(&pip.design, pip.fmax_mhz(), &m).edp(rt1);
        assert!(edp1 < edp0 * 0.5, "EDP {edp0} -> {edp1}");
    }

    #[test]
    fn magnitudes_in_table1_range() {
        // Per-cycle energy should be in the ~0.3-3 nJ band so Table I
        // powers (85-903 mW at 30-617 MHz) are reachable.
        let ctx = CompileCtx::paper();
        let app = crate::apps::dense::gaussian(6400, 4800, 16);
        let c = compile(&app, &ctx, &PipelineConfig::none(), 3).unwrap();
        let m = EnergyModel::default();
        let p = estimate(&c.design, c.fmax_mhz(), &m);
        assert!(
            (0.2..4.0).contains(&p.energy_per_cycle_nj),
            "E/cycle {} nJ",
            p.energy_per_cycle_nj
        );
        assert!(p.total_mw() > 30.0 && p.total_mw() < 1200.0, "{} mW", p.total_mw());
    }
}
