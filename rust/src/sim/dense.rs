//! Fabric-level cycle-accurate simulation of a routed dense design.
//!
//! Unlike `dfg::interp` (which interprets the *logical* graph with per-edge
//! register counts), this simulator executes the *physical* design: each
//! sink's value is produced by walking its routed path through the actual
//! enabled switch-box registers (one state element per enabled SbOut,
//! shared across the sinks downstream of it), the register-file delay lines
//! allocated by realization, and the PE input registers. Agreement with the
//! interpreter therefore checks placement/routing/realization/branch-delay
//! matching end to end.

use std::collections::{BTreeMap, HashMap, VecDeque};

use crate::arch::canal::{Layer, NodeId as RrgNode};
use crate::dfg::ir::{AluOp, EdgeId, Op};
use crate::pnr::RoutedDesign;

/// Input-port slots per node in the flat edge lookup: 4 ports x 2 layers.
const PORT_SLOTS: usize = 8;

#[inline]
fn slot_of(node: u32, port: u8, layer: Layer) -> usize {
    node as usize * PORT_SLOTS + (port as usize) * 2 + layer.index()
}

/// Result of a fabric simulation run.
pub struct FabricRun {
    pub outputs: BTreeMap<u16, Vec<i64>>,
    pub cycles: u64,
    /// Activity counters for the power model.
    pub activity: Activity,
}

/// Switching-activity counters accumulated during simulation.
#[derive(Debug, Clone, Default)]
pub struct Activity {
    /// ALU operations executed (per op class aggregate).
    pub pe_ops: u64,
    /// Multiplier-class operations (higher energy).
    pub pe_mul_ops: u64,
    /// MEM reads+writes.
    pub mem_accesses: u64,
    /// SB hops traversed by live data (wire+mux switching).
    pub sb_hops: u64,
    /// Register updates (SB pipelining regs + PE input regs + RF words).
    pub reg_writes: u64,
    /// IO transfers.
    pub io_words: u64,
}

/// Fabric simulator state.
pub struct FabricSim<'a> {
    d: &'a RoutedDesign,
    order: Vec<u32>,
    /// Current-cycle output value per DFG node.
    value: Vec<i64>,
    /// Enabled SB register states.
    sb_state: HashMap<RrgNode, i64>,
    /// Register-file delay lines per edge.
    rf_lines: HashMap<EdgeId, VecDeque<i64>>,
    /// PE input register state per node.
    in_regs: Vec<[i64; 2]>,
    /// Delay-node (line buffer / shift register) storage.
    delay_q: Vec<VecDeque<i64>>,
    /// ROM counters.
    rom_ctr: Vec<u64>,
    /// Accumulators: (acc, update count, schedule start offset, out reg).
    acc: Vec<(i64, u64, u64, i64)>,
    /// Per-edge: number of enabled SB regs on its path (cached), and the
    /// ordered list of those reg nodes.
    edge_regs: Vec<Vec<RrgNode>>,
    /// Hops per edge (for activity).
    edge_hops: Vec<u32>,
    /// Flat (node, port, layer) -> edge index lookup (u32::MAX = none).
    edge_of: Vec<u32>,
    cycle: u64,
    pub activity: Activity,
}

impl<'a> FabricSim<'a> {
    pub fn new(d: &'a RoutedDesign) -> FabricSim<'a> {
        let n = d.dfg.nodes.len();
        let mut edge_regs = Vec::with_capacity(d.dfg.edges.len());
        let mut edge_hops = Vec::with_capacity(d.dfg.edges.len());
        for ei in 0..d.dfg.edges.len() {
            let regs: Vec<RrgNode> = d
                .edge_path(ei as EdgeId)
                .map(|p| p.iter().copied().filter(|x| d.sb_regs.contains(x)).collect())
                .unwrap_or_default();
            edge_hops.push(d.edge_path(ei as EdgeId).map(|p| p.len() as u32).unwrap_or(0));
            edge_regs.push(regs);
        }
        let rf_lines = d
            .rf_delay
            .iter()
            .map(|(&e, &k)| (e, VecDeque::from(vec![0i64; k as usize])))
            .collect();
        let delay_q = d
            .dfg
            .nodes
            .iter()
            .map(|nd| match &nd.op {
                Op::Delay { cycles, .. } => VecDeque::from(vec![0i64; *cycles as usize]),
                _ => VecDeque::new(),
            })
            .collect();
        // Schedule offsets (§V-F): accumulators begin counting when their
        // pipelining-delayed input stream starts.
        let added = crate::pipeline::bdm::added_arrival_cycles(&d.dfg);
        let accum_starts: Vec<(i64, u64, u64, i64)> = d
            .dfg
            .nodes
            .iter()
            .enumerate()
            .map(|(i, nd)| {
                let start = if matches!(nd.op, Op::Accum { .. }) {
                    d.dfg
                        .edges
                        .iter()
                        .filter(|e| e.dst == i as u32 && e.dst_port == 0 && e.layer == Layer::B16)
                        .map(|e| added[e.src as usize] + e.regs as u64)
                        .max()
                        .unwrap_or(0)
                } else {
                    0
                };
                (0i64, 0u64, start, 0i64)
            })
            .collect();
        let mut edge_of = vec![u32::MAX; d.dfg.nodes.len() * PORT_SLOTS];
        for (ei, e) in d.dfg.edges.iter().enumerate() {
            edge_of[slot_of(e.dst, e.dst_port, e.layer)] = ei as u32;
        }
        FabricSim {
            d,
            order: d.dfg.topo_order(),
            edge_regs,
            edge_hops,
            edge_of,
            value: vec![0; n],
            sb_state: d.sb_regs.iter().map(|&r| (r, 0i64)).collect(),
            rf_lines,
            in_regs: vec![[0, 0]; n],
            delay_q,
            rom_ctr: vec![0; n],
            acc: accum_starts,
            cycle: 0,
            activity: Activity::default(),
        }
    }

    /// Value arriving at an edge's sink this cycle: the driver's value
    /// passed through the edge's enabled SB registers and RF delay line.
    /// (Register states are updated in the commit phase.)
    fn edge_value(&self, ei: EdgeId) -> i64 {
        let regs = &self.edge_regs[ei as usize];
        let e = self.d.dfg.edge(ei);
        let v = if let Some(&last) = regs.last() {
            // Value after the last SB register on the path.
            self.sb_state[&last]
        } else {
            self.value[e.src as usize]
        };
        if let Some(line) = self.rf_lines.get(&ei) {
            if !line.is_empty() {
                return *line.front().unwrap();
            }
        }
        v
    }

    fn input_val(&self, dst: u32, port: u8, layer: Layer) -> i64 {
        let ei = self.edge_of[slot_of(dst, port, layer)];
        if ei == u32::MAX {
            0
        } else {
            self.edge_value(ei)
        }
    }

    /// Advance one cycle.
    pub fn step(&mut self, inputs: &BTreeMap<u16, Vec<i64>>) {
        let t = self.cycle;
        // Phase 1: compute node outputs in topo order.
        for idx in 0..self.order.len() {
            let n = self.order[idx];
            let node = &self.d.dfg.nodes[n as usize];
            let v = match &node.op {
                Op::Input { lane } => {
                    self.activity.io_words += 1;
                    inputs.get(lane).and_then(|s| s.get(t as usize)).copied().unwrap_or(0)
                }
                Op::Output { .. } => self.input_val(n, 0, Layer::B16),
                Op::Const { value } => *value,
                Op::FlushSrc => i64::from(t == 0),
                Op::Alu { op, const_b } => {
                    self.activity.pe_ops += 1;
                    if matches!(op, AluOp::Mul | AluOp::Mac) {
                        self.activity.pe_mul_ops += 1;
                    }
                    let (a, b) = if node.input_regs {
                        let r = self.in_regs[n as usize];
                        (r[0], const_b.unwrap_or(r[1]))
                    } else {
                        (
                            self.input_val(n, 0, Layer::B16),
                            const_b.unwrap_or_else(|| self.input_val(n, 1, Layer::B16)),
                        )
                    };
                    let sel = self.input_val(n, 0, Layer::B1);
                    op.eval(a, b, if *op == AluOp::Mux { sel } else { 0 })
                }
                Op::Fused { ops } => {
                    // One PE executes the whole chain each cycle: every
                    // member op switches.
                    self.activity.pe_ops += ops.len() as u64;
                    self.activity.pe_mul_ops +=
                        ops.iter().filter(|s| matches!(s.op, AluOp::Mul | AluOp::Mac)).count()
                            as u64;
                    let head_cb = ops[0].const_b;
                    let (a, b) = if node.input_regs {
                        let r = self.in_regs[n as usize];
                        (r[0], head_cb.unwrap_or(r[1]))
                    } else {
                        (
                            self.input_val(n, 0, Layer::B16),
                            head_cb.unwrap_or_else(|| self.input_val(n, 1, Layer::B16)),
                        )
                    };
                    crate::dfg::ir::eval_fused(ops, a, b)
                }
                Op::Delay { cycles, .. } => {
                    self.activity.mem_accesses += 2; // read + write per cycle
                    if *cycles == 0 {
                        self.input_val(n, 0, Layer::B16)
                    } else {
                        *self.delay_q[n as usize].front().unwrap()
                    }
                }
                Op::Rom { values } => {
                    self.activity.mem_accesses += 1;
                    // Generator starts one cycle early (schedule offset),
                    // so word k is on the output during execution cycle k.
                    values[(self.rom_ctr[n as usize] as usize) % values.len()]
                }
                Op::Accum { .. } => self.acc[n as usize].3,
                Op::Sparse(_) => {
                    panic!("FabricSim simulates statically scheduled designs; use sim::sparse")
                }
            };
            self.value[n as usize] = v;
        }

        // Phase 2: commit registered state. All register inputs must be
        // sampled from the *pre-commit* fabric state (they all clock on
        // the same edge), so snapshot them first.
        let mut pe_samples: Vec<(u32, [i64; 2])> = Vec::new();
        let mut delay_samples: Vec<(u32, i64)> = Vec::new();
        let mut accum_samples: Vec<(u32, i64, i64)> = Vec::new();
        for n in 0..self.d.dfg.nodes.len() as u32 {
            let node = &self.d.dfg.nodes[n as usize];
            match &node.op {
                Op::Alu { .. } | Op::Fused { .. } if node.input_regs => {
                    let a = self.input_val(n, 0, Layer::B16);
                    let b = self.input_val(n, 1, Layer::B16);
                    pe_samples.push((n, [a, b]));
                }
                Op::Delay { cycles, .. } if *cycles > 0 => {
                    delay_samples.push((n, self.input_val(n, 0, Layer::B16)));
                }
                Op::Accum { .. } => {
                    let a = self.input_val(n, 0, Layer::B16);
                    let has_b = self
                        .d
                        .dfg
                        .edges
                        .iter()
                        .any(|e| e.dst == n && e.dst_port == 1 && e.layer == Layer::B16);
                    let b = if has_b { self.input_val(n, 1, Layer::B16) } else { 1 };
                    accum_samples.push((n, a, b));
                }
                _ => {}
            }
        }
        // 2a. RF delay lines shift in the post-SB-register value.
        let rf_edges: Vec<EdgeId> = self.rf_lines.keys().copied().collect();
        for ei in rf_edges {
            let regs = &self.edge_regs[ei as usize];
            let e = self.d.dfg.edge(ei);
            let v = if let Some(&last) = regs.last() {
                self.sb_state[&last]
            } else {
                self.value[e.src as usize]
            };
            let line = self.rf_lines.get_mut(&ei).unwrap();
            if !line.is_empty() {
                line.push_back(v);
                line.pop_front();
                self.activity.reg_writes += 1;
            }
        }
        // 2b. SB registers: each captures the value upstream of it on its
        // path. Process per edge path, last-to-first so chained registers
        // shift correctly within one cycle.
        let mut new_sb: Vec<(RrgNode, i64)> = Vec::new();
        for ei in 0..self.d.dfg.edges.len() {
            let regs = &self.edge_regs[ei];
            if regs.is_empty() {
                continue;
            }
            let src = self.d.dfg.edge(ei as EdgeId).src;
            for (k, &r) in regs.iter().enumerate() {
                let upstream = if k == 0 {
                    self.value[src as usize]
                } else {
                    self.sb_state[&regs[k - 1]]
                };
                new_sb.push((r, upstream));
            }
        }
        for (r, v) in new_sb {
            self.sb_state.insert(r, v);
            self.activity.reg_writes += 1;
        }
        // 2c. PE input regs (pre-commit samples).
        for (n, ab) in pe_samples {
            self.in_regs[n as usize] = ab;
            self.activity.reg_writes += 2;
        }
        // 2d. Node state (pre-commit samples).
        for (n, vin) in delay_samples {
            let q = &mut self.delay_q[n as usize];
            q.push_back(vin);
            q.pop_front();
        }
        for (n, a, b) in accum_samples {
            if let Op::Accum { period } = self.d.dfg.nodes[n as usize].op {
                let cycle = self.cycle;
                let (acc, ctr, start, out) = &mut self.acc[n as usize];
                if cycle >= *start {
                    *acc += a * b;
                    *ctr += 1;
                    if period > 0 && *ctr % (period as u64) == 0 {
                        *out = *acc;
                        *acc = 0;
                    }
                }
            }
        }
        for n in 0..self.d.dfg.nodes.len() {
            if matches!(self.d.dfg.nodes[n].op, Op::Rom { .. }) {
                self.rom_ctr[n] += 1;
            }
        }
        // Activity: live hops.
        for h in &self.edge_hops {
            self.activity.sb_hops += *h as u64;
        }
        self.cycle += 1;
    }

    /// Run for `cycles`, recording outputs.
    pub fn run(d: &'a RoutedDesign, inputs: &BTreeMap<u16, Vec<i64>>, cycles: u64) -> FabricRun {
        let mut sim = FabricSim::new(d);
        let out_nodes: Vec<(u16, u32)> = d
            .dfg
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| match n.op {
                Op::Output { lane, .. } => Some((lane, i as u32)),
                _ => None,
            })
            .collect();
        let mut outputs: BTreeMap<u16, Vec<i64>> =
            out_nodes.iter().map(|&(l, _)| (l, Vec::new())).collect();
        for _ in 0..cycles {
            sim.step(inputs);
            for &(lane, node) in &out_nodes {
                outputs.get_mut(&lane).unwrap().push(sim.value[node as usize]);
            }
        }
        FabricRun { outputs, cycles, activity: sim.activity }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::interp::Interp;
    use crate::pipeline::{compile, CompileCtx, PipelineConfig};

    fn compare_fabric_to_interp(app: crate::apps::App, cfg: &PipelineConfig, seed: u64) {
        let ctx = CompileCtx::paper();
        let c = compile(&app, &ctx, cfg, seed).unwrap();
        c.design.registers_consistent().unwrap();
        let lanes: Vec<u16> = app
            .dfg
            .nodes
            .iter()
            .filter_map(|n| match n.op {
                Op::Input { lane } => Some(lane),
                _ => None,
            })
            .collect();
        let mut inputs = BTreeMap::new();
        for (k, lane) in lanes.iter().enumerate() {
            inputs.insert(
                *lane,
                (0..600).map(|x| ((x * 7 + k as i64 * 13 + 5) % 29) as i64).collect::<Vec<i64>>(),
            );
        }
        let cycles = 600;
        let logical = Interp::run(&c.design.dfg, &inputs, cycles);
        let fabric = FabricSim::run(&c.design, &inputs, cycles);
        for (lane, vals) in &logical.outputs {
            assert_eq!(
                vals, &fabric.outputs[lane],
                "{}: lane {lane} fabric != interp",
                app.name
            );
        }
    }

    #[test]
    fn fabric_matches_interp_unpipelined() {
        compare_fabric_to_interp(
            crate::apps::dense::gaussian(64, 8, 1),
            &PipelineConfig::none(),
            3,
        );
    }

    #[test]
    fn fabric_matches_interp_compute_pipelined() {
        compare_fabric_to_interp(
            crate::apps::dense::gaussian(64, 8, 1),
            &PipelineConfig::compute_only(),
            3,
        );
    }

    #[test]
    fn fabric_matches_interp_full_postpnr() {
        compare_fabric_to_interp(
            crate::apps::dense::unsharp(64, 8, 1),
            &PipelineConfig::with_postpnr(),
            5,
        );
    }

    #[test]
    fn fabric_matches_interp_camera_mux() {
        compare_fabric_to_interp(
            crate::apps::dense::camera(64, 8, 1),
            &PipelineConfig::with_postpnr(),
            7,
        );
    }

    #[test]
    fn fabric_matches_interp_resnet() {
        compare_fabric_to_interp(
            crate::apps::dense::resnet_small(),
            &PipelineConfig::with_postpnr(),
            9,
        );
    }

    #[test]
    fn activity_counters_accumulate() {
        let ctx = CompileCtx::paper();
        let app = crate::apps::dense::gaussian(64, 8, 1);
        let c = compile(&app, &ctx, &PipelineConfig::compute_only(), 3).unwrap();
        let mut inputs = BTreeMap::new();
        inputs.insert(0u16, vec![1i64; 100]);
        let run = FabricSim::run(&c.design, &inputs, 100);
        assert!(run.activity.pe_ops > 0);
        assert!(run.activity.mem_accesses > 0);
        assert!(run.activity.reg_writes > 0);
        assert!(run.activity.sb_hops > 0);
    }
}
