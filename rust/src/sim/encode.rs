//! Bitstream generation (the final stage of the Fig. 2 compiler) and a
//! structural decoder used for round-trip verification and the low
//! unrolling duplication stamping (§V-E).

use crate::arch::bitstream::{
    encode_sb_source, Bitstream, ConfigSpace, Feature, SbSource, CB_UNUSED,
};
use crate::arch::canal::{InterconnectGraph, NodeKind};
use crate::dfg::ir::{EdgeId, Op};
use crate::pnr::RoutedDesign;
use crate::schedule::Schedule;

/// MEM tile modes.
pub const MEM_UNUSED: u32 = 0;
pub const MEM_ROM: u32 = 1;
pub const MEM_LINEBUF: u32 = 2;
pub const MEM_SCHED: u32 = 3;

/// IO tile modes.
pub const IO_IN: u32 = 1;
pub const IO_OUT: u32 = 2;

/// Encode a [`crate::pipeline::Compiled`] artifact — fresh from the
/// compiler or rehydrated from the explore artifact store — into
/// configuration words. Builds its own interconnect graph from the
/// design's architecture: graph construction depends only on the
/// structural parameters (not `hardened_flush`, the one knob on which a
/// design's arch can differ from its compile context's base), so the node
/// ids match the ones recorded in the routes, and `cascade encode
/// --from-cache` is byte-identical to encoding a fresh compile.
pub fn encode_compiled(c: &crate::pipeline::Compiled) -> Bitstream {
    let graph = InterconnectGraph::build(&c.design.arch);
    encode(&c.design, &c.schedule, &graph)
}

/// Encode a routed design + schedule into configuration words.
pub fn encode(d: &RoutedDesign, sched: &Schedule, graph: &InterconnectGraph) -> Bitstream {
    let arch = &d.arch;
    let cs = ConfigSpace::new(arch);
    let mut bs = Bitstream::new();

    // --- Interconnect: walk every route, configure SB muxes and CB taps.
    for route in &d.routes {
        for path in &route.sink_paths {
            for w in path.windows(2) {
                let (a, b) = (graph.decode(w[0]), graph.decode(w[1]));
                match b.kind {
                    NodeKind::SbOut { side, track } => {
                        let src = match a.kind {
                            NodeKind::SbIn { side: in_side, .. } => SbSource::In { side: in_side },
                            NodeKind::TileOut { port } => SbSource::TileOut { port },
                            _ => unreachable!("invalid SbOut driver"),
                        };
                        bs.set(
                            arch,
                            &cs,
                            b.tile,
                            Feature::SbSel { layer: b.layer, side, track },
                            encode_sb_source(side, src),
                        );
                    }
                    NodeKind::CbIn { port } => {
                        if let NodeKind::SbIn { side, track } = a.kind {
                            bs.set(
                                arch,
                                &cs,
                                b.tile,
                                Feature::CbSel { layer: b.layer, port },
                                side.index() as u32 * arch.tracks as u32 + track as u32 + 1,
                            );
                        }
                    }
                    _ => {}
                }
            }
        }
    }

    // --- Enabled SB pipelining registers.
    for &r in &d.sb_regs {
        let n = graph.decode(r);
        if let NodeKind::SbOut { side, track } = n.kind {
            bs.set(arch, &cs, n.tile, Feature::SbRegEn { layer: n.layer, side, track }, 1);
        }
    }

    // --- Tiles.
    for (i, node) in d.dfg.nodes.iter().enumerate() {
        let tile = d.placement.pos[i];
        match &node.op {
            Op::Alu { op, const_b } => {
                bs.set(arch, &cs, tile, Feature::PeOp, op.encode());
                if node.input_regs {
                    for port in 0..arch.data_in_ports as u8 {
                        bs.set(arch, &cs, tile, Feature::PeInRegEn { port }, 1);
                    }
                }
                if let Some(c) = const_b {
                    bs.set(arch, &cs, tile, Feature::PeConst, (*c as i32 as u32) & 0xFFFF);
                }
            }
            Op::Fused { ops } => {
                // Head step configures the PE like a plain ALU; tail steps
                // ride in the high MEM-param words (8..), disjoint from
                // the schedule's generator words (1..=5) and the Accum
                // period word (0).
                bs.set(arch, &cs, tile, Feature::PeOp, ops[0].op.encode());
                if node.input_regs {
                    for port in 0..arch.data_in_ports as u8 {
                        bs.set(arch, &cs, tile, Feature::PeInRegEn { port }, 1);
                    }
                }
                if let Some(c) = ops[0].const_b {
                    bs.set(arch, &cs, tile, Feature::PeConst, (c as i32 as u32) & 0xFFFF);
                }
                for (k, s) in ops[1..].iter().enumerate() {
                    let has_c = u32::from(s.const_b.is_some());
                    let imm = (s.const_b.unwrap_or(0) as i32 as u32) & 0xFFFF;
                    bs.set(
                        arch,
                        &cs,
                        tile,
                        Feature::MemParam { idx: 8 + k as u8 },
                        (s.op.encode() << 17) | (has_c << 16) | imm,
                    );
                }
            }
            Op::Delay { cycles, .. } => {
                if node.tile_kind() == crate::arch::params::TileKind::Mem {
                    bs.set(arch, &cs, tile, Feature::MemMode, MEM_LINEBUF);
                    bs.set(arch, &cs, tile, Feature::MemParam { idx: 0 }, *cycles);
                } else {
                    // PE register-file delay line.
                    bs.set(arch, &cs, tile, Feature::PeOp, crate::dfg::ir::AluOp::Pass.encode());
                    bs.set(arch, &cs, tile, Feature::PeRfDelay { port: 0 }, *cycles);
                }
            }
            Op::Rom { values } => {
                bs.set(arch, &cs, tile, Feature::MemMode, MEM_ROM);
                bs.set(arch, &cs, tile, Feature::MemParam { idx: 0 }, values.len() as u32);
            }
            Op::Accum { period } => {
                bs.set(arch, &cs, tile, Feature::PeOp, crate::dfg::ir::AluOp::Mac.encode());
                bs.set(arch, &cs, tile, Feature::MemParam { idx: 0 }, *period);
            }
            Op::Input { .. } | Op::FlushSrc => {
                bs.set(arch, &cs, tile, Feature::IoMode, IO_IN);
            }
            Op::Output { .. } => {
                bs.set(arch, &cs, tile, Feature::IoMode, IO_OUT);
            }
            Op::Sparse(_) => {
                let is_mem = node.tile_kind() == crate::arch::params::TileKind::Mem;
                bs.set(
                    arch,
                    &cs,
                    tile,
                    if is_mem { Feature::MemMode } else { Feature::PeOp },
                    if is_mem { MEM_SCHED } else { crate::dfg::ir::AluOp::Add.encode() },
                );
                for port in 0..arch.data_in_ports as u8 {
                    bs.set(arch, &cs, tile, Feature::FifoEn { port }, 1);
                }
            }
            Op::Const { .. } => {}
        }
        // Schedule offsets for MEM generators.
        if let Some(ms) = sched.mem_params.get(&(i as u32)) {
            bs.set(arch, &cs, tile, Feature::MemParam { idx: 1 }, ms.start_offset);
            for (k, &ext) in ms.extents.iter().take(4).enumerate() {
                bs.set(arch, &cs, tile, Feature::MemParam { idx: 2 + k as u8 }, ext);
            }
        }
        // Register-file delays allocated on input edges.
        for (ei, e) in d.dfg.edges.iter().enumerate() {
            if e.dst == i as u32 {
                if let Some(&k) = d.rf_delay.get(&(ei as EdgeId)) {
                    if k > 0 {
                        bs.set(arch, &cs, tile, Feature::PeRfDelay { port: e.dst_port }, k);
                    }
                }
            }
        }
    }
    bs
}

/// Structural decode: rebuild the set of (tile, SB mux configs, CB taps,
/// reg enables) and verify them against the design. Returns problems.
pub fn verify_roundtrip(
    d: &RoutedDesign,
    bs: &Bitstream,
    graph: &InterconnectGraph,
) -> Vec<String> {
    let arch = &d.arch;
    let cs = ConfigSpace::new(arch);
    let mut problems = Vec::new();

    // Every enabled SB register must decode back on.
    for &r in &d.sb_regs {
        let n = graph.decode(r);
        if let NodeKind::SbOut { side, track } = n.kind {
            if bs.get(arch, &cs, n.tile, Feature::SbRegEn { layer: n.layer, side, track }) != 1 {
                problems.push(format!("missing SbRegEn at {:?}", n));
            }
        }
    }
    // Every routed SbOut hop must have a mux select that reproduces its
    // driver.
    for route in &d.routes {
        for path in &route.sink_paths {
            for w in path.windows(2) {
                let (a, b) = (graph.decode(w[0]), graph.decode(w[1]));
                if let NodeKind::SbOut { side, track } = b.kind {
                    let v =
                        bs.get(arch, &cs, b.tile, Feature::SbSel { layer: b.layer, side, track });
                    let decoded = crate::arch::bitstream::decode_sb_source(side, v);
                    let expect = match a.kind {
                        NodeKind::SbIn { side: s, .. } => SbSource::In { side: s },
                        NodeKind::TileOut { port } => SbSource::TileOut { port },
                        _ => unreachable!(),
                    };
                    if decoded != expect {
                        problems
                            .push(format!("SbSel mismatch at {:?}: {decoded:?} != {expect:?}", b));
                    }
                } else if let NodeKind::CbIn { port } = b.kind {
                    let v = bs.get(arch, &cs, b.tile, Feature::CbSel { layer: b.layer, port });
                    if v == 0 || v == CB_UNUSED {
                        problems.push(format!("CbSel unset at {:?}", b));
                    }
                }
            }
        }
    }
    // Every PE's opcode survives (compound heads encode like plain ALUs;
    // their tail steps must decode back from the MEM-param words).
    for (i, node) in d.dfg.nodes.iter().enumerate() {
        let tile = d.placement.pos[i];
        match &node.op {
            Op::Alu { op, .. } => {
                if bs.get(arch, &cs, tile, Feature::PeOp) != op.encode() {
                    problems.push(format!("PeOp mismatch at node {i}"));
                }
            }
            Op::Fused { ops } => {
                if bs.get(arch, &cs, tile, Feature::PeOp) != ops[0].op.encode() {
                    problems.push(format!("fused head PeOp mismatch at node {i}"));
                }
                for (k, s) in ops[1..].iter().enumerate() {
                    let v = bs.get(arch, &cs, tile, Feature::MemParam { idx: 8 + k as u8 });
                    let dec_op = crate::dfg::ir::AluOp::decode(v >> 17);
                    let dec_has_c = (v >> 16) & 1 == 1;
                    let want_imm = (s.const_b.unwrap_or(0) as i32 as u32) & 0xFFFF;
                    if dec_op != Some(s.op)
                        || dec_has_c != s.const_b.is_some()
                        || (v & 0xFFFF) != want_imm
                    {
                        problems.push(format!("fused tail step {k} mismatch at node {i}"));
                    }
                }
            }
            _ => {}
        }
    }
    problems
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::{compile, CompileCtx, PipelineConfig};

    #[test]
    fn roundtrip_clean_for_pipelined_design() {
        let ctx = CompileCtx::paper();
        let app = crate::apps::dense::gaussian(64, 64, 2);
        let c = compile(&app, &ctx, &PipelineConfig::with_postpnr(), 3).unwrap();
        let bs = encode(&c.design, &c.schedule, &ctx.graph);
        assert!(bs.len() > 100, "bitstream suspiciously small: {}", bs.len());
        let problems = verify_roundtrip(&c.design, &bs, &ctx.graph);
        assert!(problems.is_empty(), "{problems:?}");
    }

    /// `encode_compiled` (the artifact-store consumer's entry point) must
    /// agree exactly with encoding through the compile context's graph.
    #[test]
    fn encode_compiled_matches_ctx_graph_encoding() {
        let ctx = CompileCtx::paper();
        let app = crate::apps::dense::gaussian(64, 64, 2);
        // `full` exercises the hardened-flush divergence between the
        // design's arch and the context's base arch.
        let c = compile(&app, &ctx, &PipelineConfig::full(), 3).unwrap();
        let via_ctx = encode(&c.design, &c.schedule, &ctx.graph);
        let via_artifact = encode_compiled(&c);
        assert_eq!(via_ctx.to_text(), via_artifact.to_text());
    }

    #[test]
    fn roundtrip_clean_for_fused_design() {
        let ctx = CompileCtx::paper();
        let app = crate::apps::dense::unsharp(64, 64, 1);
        let cfg = PipelineConfig { fusion: true, ..PipelineConfig::with_postpnr() };
        let c = compile(&app, &ctx, &cfg, 3).unwrap();
        assert!(
            c.design.dfg.nodes.iter().any(|n| matches!(n.op, Op::Fused { .. })),
            "unsharp has fusible chains"
        );
        let bs = encode(&c.design, &c.schedule, &ctx.graph);
        let problems = verify_roundtrip(&c.design, &bs, &ctx.graph);
        assert!(problems.is_empty(), "{problems:?}");
    }

    #[test]
    fn sparse_design_sets_fifo_enables() {
        let ctx = CompileCtx::paper();
        let app = crate::apps::sparse::vec_elemadd(1024, 0.2);
        let c = compile(&app, &ctx, &PipelineConfig::compute_only(), 5).unwrap();
        let bs = encode(&c.design, &c.schedule, &ctx.graph);
        let cs = ConfigSpace::new(&c.design.arch);
        let mut fifo_feats = 0;
        for (_, f, v) in bs.features(&c.design.arch, &cs) {
            if matches!(f, Feature::FifoEn { .. }) && v == 1 {
                fifo_feats += 1;
            }
        }
        assert!(fifo_feats > 0);
    }

    #[test]
    fn pipelining_grows_bitstream() {
        let ctx = CompileCtx::paper();
        let app = crate::apps::dense::unsharp(64, 64, 1);
        let c0 = compile(&app, &ctx, &PipelineConfig::none(), 3).unwrap();
        let c1 = compile(&app, &ctx, &PipelineConfig::with_postpnr(), 3).unwrap();
        let b0 = encode(&c0.design, &c0.schedule, &ctx.graph);
        let b1 = encode(&c1.design, &c1.schedule, &ctx.graph);
        // Pipelined designs carry register-enable words.
        assert!(b1.len() > b0.len());
    }

    #[test]
    fn duplication_stamp_on_encoded_design() {
        // Low-unroll flow: encode the region design, stamp it across the
        // array, and check the copies carry identical tile configs.
        let ctx = CompileCtx::paper();
        let c = crate::pipeline::compile_with_dup(
            &|w, h, u| crate::apps::dense::gaussian(w, h, u),
            256,
            64,
            8,
            &ctx,
            &PipelineConfig::with_postpnr(),
            5,
        )
        .unwrap();
        let plan = c.dup.clone().unwrap();
        let mut bs = encode(&c.design, &c.schedule, &ctx.graph);
        let cs = ConfigSpace::new(&c.design.arch);
        let before = bs.len();
        let copies = crate::pipeline::unroll::stamp_bitstream(&mut bs, &plan, &c.design.arch, &cs);
        assert!(copies >= 2);
        assert!(bs.len() > before, "stamping must add config words");
    }
}
