//! Fabric simulation and the power/EDP model.
//!
//! * [`dense`] — cycle-accurate simulation of a *routed, configured* design
//!   at the fabric level: values travel along the actual route trees,
//!   through the actual enabled switch-box registers, register-file delay
//!   lines and PE input registers. Verified against the logical DFG
//!   interpreter (`dfg::interp`) and, in the end-to-end example, against
//!   the AOT-compiled JAX/Pallas golden model via PJRT.
//! * [`power`] — the activity-based power and EDP model used to reproduce
//!   Table I/II and Figs. 8/11.
//! * [`encode`] — bitstream generation from a routed design (Fig. 2's last
//!   stage) with a structural decode used for round-trip tests and the low
//!   unrolling duplication stamping.

pub mod dense;
pub mod power;
pub mod encode;
