//! Net extraction: DFG edges -> physical nets.
//!
//! One net per (driver node, layer). Sparse data edges additionally expand
//! into a valid companion net (same direction, 1-bit layer) and one ready
//! net per consumer (opposite direction). The flush broadcast becomes a
//! high-fanout 1-bit net unless the architecture hardens it (§VI), in which
//! case it is carried by the dedicated per-column network and never touches
//! the configurable interconnect.

use crate::arch::canal::Layer;
use crate::arch::params::ArchParams;
use crate::dfg::ir::{Dfg, EdgeId, NodeId, Op};

/// Physical role of a net.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetKind {
    /// 16-bit data (or 1-bit control data like a mux select).
    Data,
    /// Sparse valid companion (follows its data net exactly).
    Valid,
    /// Sparse ready companion (same endpoints, opposite direction).
    Ready,
    /// The flush broadcast (§VI).
    Flush,
}

/// A physical net to place/route.
#[derive(Debug, Clone)]
pub struct Net {
    pub id: usize,
    pub kind: NetKind,
    pub layer: Layer,
    /// Driving DFG node.
    pub src: NodeId,
    /// Source TileOut port on `layer`.
    pub src_port: u8,
    /// (sink DFG node, CbIn port on `layer`) pairs.
    pub sinks: Vec<(NodeId, u8)>,
    /// DFG edges this net realizes (for Data nets; companions reference
    /// the same edges).
    pub edges: Vec<EdgeId>,
    /// For Valid/Ready nets: the id of the Data net they accompany.
    pub companion_of: Option<usize>,
}

impl Net {
    pub fn fanout(&self) -> usize {
        self.sinks.len()
    }
}

/// Extract all nets from a DFG.
pub fn build_nets(g: &Dfg, arch: &ArchParams) -> Vec<Net> {
    let mut nets: Vec<Net> = Vec::new();

    // Group edges by (src, layer).
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<(NodeId, u8), Vec<EdgeId>> = BTreeMap::new();
    for (ei, e) in g.edges.iter().enumerate() {
        groups
            .entry((e.src, e.layer.index() as u8))
            .or_default()
            .push(ei as EdgeId);
    }

    for ((src, layer_idx), edges) in groups {
        let layer = if layer_idx == 0 { Layer::B16 } else { Layer::B1 };
        let is_flush = matches!(g.node(src).op, Op::FlushSrc);
        if is_flush && arch.hardened_flush {
            // Hardened: distributed on the dedicated column network; not a
            // routed net at all.
            continue;
        }
        let kind = if is_flush { NetKind::Flush } else { NetKind::Data };
        let sinks: Vec<(NodeId, u8)> = edges
            .iter()
            .map(|&ei| {
                let e = g.edge(ei);
                (e.dst, e.dst_port)
            })
            .collect();
        let id = nets.len();
        nets.push(Net {
            id,
            kind,
            layer,
            src,
            src_port: 0,
            sinks,
            edges: edges.clone(),
            companion_of: None,
        });

        // Sparse companions: a data edge between sparse endpoints carries
        // valid (same direction) and ready (reverse).
        let sparse_net = layer == Layer::B16
            && (g.node(src).is_sparse()
                || edges.iter().any(|&ei| g.node(g.edge(ei).dst).is_sparse()));
        if sparse_net {
            let data_id = id;
            // Valid: same src/sinks on B1; CbIn B1 port = data port.
            let vid = nets.len();
            let vsinks: Vec<(NodeId, u8)> = edges
                .iter()
                .map(|&ei| {
                    let e = g.edge(ei);
                    (e.dst, e.dst_port)
                })
                .collect();
            nets.push(Net {
                id: vid,
                kind: NetKind::Valid,
                layer: Layer::B1,
                src,
                src_port: 0,
                sinks: vsinks,
                edges: edges.clone(),
                companion_of: Some(data_id),
            });
            // Ready: one net per consumer, driven by the consumer
            // (TileOut B1 port 1 + its in-port), sunk at the producer
            // (CbIn B1 port 2 + sink index).
            for (sink_idx, &ei) in edges.iter().enumerate() {
                let e = g.edge(ei);
                let rid = nets.len();
                nets.push(Net {
                    id: rid,
                    kind: NetKind::Ready,
                    layer: Layer::B1,
                    src: e.dst,
                    src_port: 1 + e.dst_port,
                    sinks: vec![(src, 2 + sink_idx as u8)],
                    edges: vec![ei],
                    companion_of: Some(data_id),
                });
            }
        }
    }
    nets
}

/// Statistics over a netlist.
pub fn net_stats(nets: &[Net]) -> (usize, usize, usize) {
    let data = nets.iter().filter(|n| n.kind == NetKind::Data).count();
    let companions = nets
        .iter()
        .filter(|n| matches!(n.kind, NetKind::Valid | NetKind::Ready))
        .count();
    let max_fanout = nets.iter().map(|n| n.fanout()).max().unwrap_or(0);
    (data, companions, max_fanout)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    #[test]
    fn dense_app_has_no_companions() {
        let arch = ArchParams::paper();
        let app = apps::dense::gaussian(64, 64, 1);
        let nets = build_nets(&app.dfg, &arch);
        assert!(nets.iter().all(|n| !matches!(n.kind, NetKind::Valid | NetKind::Ready)));
        let flush_nets = nets.iter().filter(|n| n.kind == NetKind::Flush).count();
        assert_eq!(flush_nets, 1);
    }

    #[test]
    fn hardened_flush_removes_net() {
        let mut arch = ArchParams::paper();
        arch.hardened_flush = true;
        let app = apps::dense::gaussian(64, 64, 1);
        let nets = build_nets(&app.dfg, &arch);
        assert!(nets.iter().all(|n| n.kind != NetKind::Flush));
    }

    #[test]
    fn sparse_edges_get_triples() {
        let arch = ArchParams::paper();
        let app = apps::sparse::vec_elemadd(1024, 0.2);
        let nets = build_nets(&app.dfg, &arch);
        let data: Vec<&Net> = nets
            .iter()
            .filter(|n| n.kind == NetKind::Data && app.dfg.node(n.src).is_sparse())
            .collect();
        for d in &data {
            // Exactly one valid companion with identical endpoints.
            let valid: Vec<&Net> = nets
                .iter()
                .filter(|n| n.kind == NetKind::Valid && n.companion_of == Some(d.id))
                .collect();
            assert_eq!(valid.len(), 1);
            assert_eq!(valid[0].src, d.src);
            assert_eq!(valid[0].sinks.len(), d.sinks.len());
            // One ready net per sink, reversed.
            let readies: Vec<&Net> = nets
                .iter()
                .filter(|n| n.kind == NetKind::Ready && n.companion_of == Some(d.id))
                .collect();
            assert_eq!(readies.len(), d.sinks.len());
            for r in readies {
                assert_eq!(r.sinks[0].0, d.src);
                assert!(d.sinks.iter().any(|&(s, _)| s == r.src));
            }
        }
    }

    #[test]
    fn ready_ports_within_capacity() {
        let arch = ArchParams::paper();
        for app in apps::paper_sparse_suite() {
            let nets = build_nets(&app.dfg, &arch);
            for n in &nets {
                for &(_, port) in &n.sinks {
                    let cap = match n.layer {
                        Layer::B16 => arch.data_in_ports,
                        Layer::B1 => arch.bit_in_ports,
                    };
                    assert!((port as usize) < cap, "{}: port {port} on {:?}", app.name, n.kind);
                }
                let out_cap = match n.layer {
                    Layer::B16 => arch.data_out_ports,
                    Layer::B1 => arch.bit_out_ports,
                };
                assert!((n.src_port as usize) < out_cap, "{}: src_port", app.name);
            }
        }
    }

    #[test]
    fn resnet_has_high_fanout_broadcast() {
        let arch = ArchParams::paper();
        let app = apps::dense::resnet_conv5x();
        let nets = build_nets(&app.dfg, &arch);
        let (_, _, max_fanout) = net_stats(&nets);
        assert!(max_fanout >= 8);
    }
}
