//! The routed design: placement + route trees + pipelining state.
//!
//! This is the object everything downstream consumes: application STA
//! (`timing::sta`), the post-PnR pipelining pass, the bitstream encoder and
//! the fabric simulator.
//!
//! ## Register realization
//!
//! The pipelining passes decide *logical* per-edge register counts
//! (`Dfg::Edge::regs`, maintained balanced by branch delay matching).
//! [`RoutedDesign::realize_registers`] maps those logical registers onto
//! physical resources:
//!
//! * switch-box output pipelining registers along the edge's routed path
//!   (every SbOut has one, §V-D), chosen near the middle of the
//!   unregistered span so they actually break long wires;
//! * register-file variable-length shift registers at the sink tile for
//!   any overflow (§V-A, Fig. 4 right — "we utilize register files in PE
//!   tiles to act as variable length shift registers").
//!
//! On a route *tree*, a switch-box register on a shared segment delays
//! every downstream sink; the realizer only picks a node when every sink it
//! affects still needs a register there, so the logical per-edge counts are
//! honoured exactly.

use std::collections::{HashMap, HashSet};

use crate::arch::canal::{InterconnectGraph, NodeId as RrgNode, NodeKind};
use crate::arch::delay::DelayLib;
use crate::arch::params::ArchParams;
use crate::dfg::ir::{Dfg, EdgeId, Op};

use super::netlist::{Net, NetKind};
use super::place::Placement;
use super::route::NetRoute;

/// A fully placed-and-routed application with pipelining state.
pub struct RoutedDesign {
    pub dfg: Dfg,
    pub nets: Vec<Net>,
    pub placement: Placement,
    pub routes: Vec<NetRoute>,
    pub arch: ArchParams,
    pub lib: DelayLib,
    /// Enabled switch-box output pipelining registers.
    pub sb_regs: HashSet<RrgNode>,
    /// Registers that must not be moved by re-realization (enabled directly
    /// by post-PnR pipelining to break a specific path).
    pub pinned_regs: HashSet<RrgNode>,
    /// Register-file shift-register delay at the sink of an edge (cycles).
    pub rf_delay: HashMap<EdgeId, u32>,
    /// Per DFG edge: (net index, sink index within the net) for data/flush
    /// nets. `None` for edges not routed (hardened flush).
    edge_net: Vec<Option<(usize, usize)>>,
}

impl RoutedDesign {
    pub fn new(
        dfg: Dfg,
        nets: Vec<Net>,
        placement: Placement,
        routes: Vec<NetRoute>,
        arch: ArchParams,
        lib: DelayLib,
    ) -> RoutedDesign {
        let mut edge_net = vec![None; dfg.edges.len()];
        for net in &nets {
            if matches!(net.kind, NetKind::Valid | NetKind::Ready) {
                continue; // companions share their data net's edges
            }
            for (sink_idx, &eid) in net.edges.iter().enumerate() {
                edge_net[eid as usize] = Some((net.id, sink_idx));
            }
        }
        RoutedDesign {
            dfg,
            nets,
            placement,
            routes,
            arch,
            lib,
            sb_regs: HashSet::new(),
            pinned_regs: HashSet::new(),
            rf_delay: HashMap::new(),
            edge_net,
        }
    }

    /// RRG path realizing a DFG edge (source TileOut .. sink CbIn), if it
    /// was routed.
    pub fn edge_path(&self, e: EdgeId) -> Option<&[RrgNode]> {
        let (net, sink) = self.edge_net[e as usize]?;
        Some(&self.routes[net].sink_paths[sink])
    }

    /// The (net, sink index) realizing an edge.
    pub fn edge_net_sink(&self, e: EdgeId) -> Option<(usize, usize)> {
        self.edge_net[e as usize]
    }

    /// Registers currently realized on an edge: enabled SbOut regs on its
    /// path plus its register-file delay.
    pub fn physical_regs_on_edge(&self, e: EdgeId) -> u32 {
        let from_sb = self
            .edge_path(e)
            .map(|p| p.iter().filter(|n| self.sb_regs.contains(n)).count() as u32)
            .unwrap_or(0);
        from_sb + self.rf_delay.get(&e).copied().unwrap_or(0)
    }

    /// SbOut nodes on an edge path that could take a register.
    pub fn sbout_nodes_on_edge(&self, e: EdgeId, graph: &InterconnectGraph) -> Vec<RrgNode> {
        self.edge_path(e)
            .map(|p| {
                p.iter()
                    .copied()
                    .filter(|&n| matches!(graph.decode(n).kind, NodeKind::SbOut { .. }))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Realize the logical `Dfg::Edge::regs` counts for every routed net.
    /// Pinned registers (post-PnR pipelining) are preserved; all other SB
    /// registers and RF delays are reassigned from scratch.
    pub fn realize_registers(&mut self, graph: &InterconnectGraph) {
        self.sb_regs.retain(|n| self.pinned_regs.contains(n));
        self.rf_delay.clear();
        let net_ids: Vec<usize> = self
            .nets
            .iter()
            .filter(|n| matches!(n.kind, NetKind::Data | NetKind::Flush))
            .map(|n| n.id)
            .collect();
        for ni in net_ids {
            self.realize_net(ni, graph);
        }
    }

    /// Realize registers for one net (see module docs).
    fn realize_net(&mut self, ni: usize, graph: &InterconnectGraph) {
        let net = &self.nets[ni];
        let edges = net.edges.clone();
        if edges.is_empty() {
            return;
        }
        // Remaining targets per sink, minus pinned regs already on path.
        let mut remaining: Vec<i64> = edges
            .iter()
            .map(|&e| {
                let target = self.dfg.edge(e).regs as i64;
                let pinned_on_path = self
                    .edge_path(e)
                    .map(|p| p.iter().filter(|n| self.pinned_regs.contains(n)).count() as i64)
                    .unwrap_or(0);
                target - pinned_on_path
            })
            .collect();
        // Negative remaining (pinned regs exceed target) means branch delay
        // matching has not yet absorbed a pinned register; clamp here — the
        // post-realization `registers_consistent` check catches any real
        // mismatch in the full flow.
        for r in &mut remaining {
            *r = (*r).max(0);
        }

        // SbOut candidates per sink path (excluding already enabled).
        let paths: Vec<Vec<RrgNode>> = edges
            .iter()
            .map(|&e| self.sbout_nodes_on_edge(e, graph))
            .collect();
        // Which sinks each node affects.
        let mut affects: HashMap<RrgNode, Vec<usize>> = HashMap::new();
        for (k, p) in paths.iter().enumerate() {
            for &n in p {
                affects.entry(n).or_default().push(k);
            }
        }

        loop {
            if remaining.iter().all(|&r| r <= 0) {
                break;
            }
            // Candidate nodes: unregistered, and every affected sink still
            // needs a register.
            let mut best: Option<(RrgNode, f64)> = None;
            for (&node, aff) in &affects {
                if self.sb_regs.contains(&node) {
                    continue;
                }
                if !aff.iter().all(|&k| remaining[k] > 0) {
                    continue;
                }
                // Score: prefer nodes covering many needy sinks, positioned
                // near the middle of the longest affected unregistered span.
                let coverage = aff.len() as f64;
                let mid_score: f64 = aff
                    .iter()
                    .map(|&k| {
                        let p = &paths[k];
                        let pos = p.iter().position(|&x| x == node).unwrap() as f64;
                        let len = p.len().max(1) as f64;
                        1.0 - ((pos / len) - 0.5).abs()
                    })
                    .sum::<f64>()
                    / coverage;
                let score = coverage * 10.0 + mid_score;
                if best.map(|(_, s)| score > s).unwrap_or(true) {
                    best = Some((node, score));
                }
            }
            match best {
                Some((node, _)) => {
                    self.sb_regs.insert(node);
                    for &k in &affects[&node] {
                        remaining[k] -= 1;
                    }
                }
                None => {
                    // No usable SB register: spill the rest into register
                    // files at each sink.
                    for (k, &e) in edges.iter().enumerate() {
                        if remaining[k] > 0 {
                            *self.rf_delay.entry(e).or_insert(0) += remaining[k] as u32;
                            remaining[k] = 0;
                        }
                    }
                }
            }
        }
    }

    /// Check that physical registers match the logical per-edge counts
    /// (validity invariant after `realize_registers`).
    pub fn registers_consistent(&self) -> Result<(), String> {
        for (ei, e) in self.dfg.edges.iter().enumerate() {
            if self.edge_net[ei].is_none() {
                continue;
            }
            let phys = self.physical_regs_on_edge(ei as EdgeId);
            // A FIFO stage on a sparse edge is realized as one pinned SB
            // register on the data path (plus companions), so the expected
            // physical count is regs + fifos.
            let expect = e.regs + e.fifos;
            if phys != expect {
                return Err(format!(
                    "edge {ei}: logical {expect} regs (incl. fifos), physical {phys}"
                ));
            }
        }
        Ok(())
    }

    /// Total pipelining resources in use: (SB registers, RF delay words,
    /// FIFO stages).
    pub fn pipelining_resources(&self) -> (usize, u64, u64) {
        let rf: u64 = self.rf_delay.values().map(|&v| v as u64).sum();
        let fifos: u64 = self.dfg.edges.iter().map(|e| e.fifos as u64).sum();
        (self.sb_regs.len(), rf, fifos)
    }

    /// Number of distinct tiles used only for routing (pass-throughs):
    /// tiles crossed by some route but hosting no node.
    pub fn passthrough_tiles(&self, graph: &InterconnectGraph) -> usize {
        let mut hosting: HashSet<crate::arch::params::TileCoord> = HashSet::new();
        for i in 0..self.dfg.nodes.len() {
            hosting.insert(self.placement.pos[i]);
        }
        let mut crossed = HashSet::new();
        for r in &self.routes {
            for n in r.nodes() {
                crossed.insert(graph.decode(n).tile);
            }
        }
        crossed.difference(&hosting).count()
    }

    /// Whether this design still carries a routed flush net (false when the
    /// architecture hardens it).
    pub fn has_routed_flush(&self) -> bool {
        self.nets.iter().any(|n| n.kind == NetKind::Flush)
    }

    /// Count nodes by sparse/dense for reporting.
    pub fn is_sparse_app(&self) -> bool {
        self.dfg.nodes.iter().any(|n| n.is_sparse())
    }

    /// The IO lane -> node mapping (for driving simulation).
    pub fn io_nodes(&self) -> (HashMap<u16, u32>, HashMap<u16, u32>) {
        let mut ins = HashMap::new();
        let mut outs = HashMap::new();
        for (i, n) in self.dfg.nodes.iter().enumerate() {
            match n.op {
                Op::Input { lane } => {
                    ins.insert(lane, i as u32);
                }
                Op::Output { lane, .. } => {
                    outs.insert(lane, i as u32);
                }
                _ => {}
            }
        }
        (ins, outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::delay::DelayModelParams;
    use crate::pnr::{place_and_route, PlaceParams, RouteParams};

    fn build(app: &crate::apps::App) -> (RoutedDesign, InterconnectGraph) {
        let arch = ArchParams::paper();
        let lib = DelayLib::generate(&arch, &DelayModelParams::default());
        let mut graph = InterconnectGraph::build(&arch);
        graph.annotate_delays(&lib);
        let d = place_and_route(
            &app.dfg,
            &arch,
            &graph,
            &lib,
            &PlaceParams::baseline(3),
            &RouteParams::default(),
        )
        .unwrap();
        (d, graph)
    }

    #[test]
    fn edge_paths_exist_for_all_routed_edges() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (d, _) = build(&app);
        for (ei, _e) in d.dfg.edges.iter().enumerate() {
            assert!(d.edge_path(ei as EdgeId).is_some(), "edge {ei} unrouted");
        }
    }

    #[test]
    fn realize_zero_regs_is_empty() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (mut d, graph) = build(&app);
        d.realize_registers(&graph);
        assert!(d.sb_regs.is_empty());
        assert!(d.rf_delay.is_empty());
        d.registers_consistent().unwrap();
    }

    #[test]
    fn realize_matches_logical_counts() {
        let app = crate::apps::dense::unsharp(64, 64, 1);
        let (mut d, graph) = build(&app);
        // Assign a few logical registers manually (balanced or not — the
        // realizer must honour per-edge counts exactly).
        let nedges = d.dfg.edges.len();
        for ei in 0..nedges {
            let hops = d.sbout_nodes_on_edge(ei as EdgeId, &graph).len() as u32;
            d.dfg.edge_mut(ei as EdgeId).regs = (ei as u32 % 3).min(hops + 5);
        }
        d.realize_registers(&graph);
        d.registers_consistent().unwrap();
    }

    #[test]
    fn realize_spills_to_regfiles_when_hops_exhausted() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (mut d, graph) = build(&app);
        // Demand far more registers than any path has hops.
        let e0 = 0 as EdgeId;
        d.dfg.edge_mut(e0).regs = 64;
        d.realize_registers(&graph);
        d.registers_consistent().unwrap();
        assert!(d.rf_delay.get(&e0).copied().unwrap_or(0) > 0);
    }

    #[test]
    fn pinned_regs_survive_rerealization() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (mut d, graph) = build(&app);
        // Pin a register on some edge's path; raise the logical count on
        // every edge whose path crosses the pinned node (what BDM does in
        // the real post-PnR flow).
        let e0 = 0 as EdgeId;
        let sbs = d.sbout_nodes_on_edge(e0, &graph);
        assert!(!sbs.is_empty());
        let pin = sbs[sbs.len() / 2];
        d.sb_regs.insert(pin);
        d.pinned_regs.insert(pin);
        for ei in 0..d.dfg.edges.len() {
            if d.edge_path(ei as EdgeId).map(|p| p.contains(&pin)).unwrap_or(false) {
                d.dfg.edge_mut(ei as EdgeId).regs += 1;
            }
        }
        d.realize_registers(&graph);
        assert!(d.sb_regs.contains(&pin));
        d.registers_consistent().unwrap();
    }

    #[test]
    fn broadcast_net_shared_register_counts_for_all_sinks() {
        let app = crate::apps::dense::resnet_small();
        let (mut d, graph) = build(&app);
        // Find a fanout>1 data net and add one logical register to every
        // one of its edges — the realizer may satisfy them with shared
        // prefix registers, and consistency must still hold.
        let net = d
            .nets
            .iter()
            .find(|n| n.kind == NetKind::Data && n.fanout() > 1)
            .expect("resnet has broadcast nets")
            .clone();
        for &e in &net.edges {
            d.dfg.edge_mut(e).regs = 1;
        }
        d.realize_registers(&graph);
        d.registers_consistent().unwrap();
    }

    #[test]
    fn passthrough_tiles_counted() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (d, graph) = build(&app);
        // Some routing crosses tiles that host nothing.
        let pt = d.passthrough_tiles(&graph);
        assert!(pt < d.arch.num_tiles());
    }
}
