//! PathFinder-style negotiated-congestion routing over the Canal RRG
//! (the "iteration-based routing algorithm" of the baseline compiler [16]).
//!
//! Every net is routed as a tree: the first sink by shortest path from the
//! source TileOut node, subsequent sinks from the whole partial tree.
//! Resource overuse is resolved iteratively: present congestion multiplies
//! node costs within an iteration, historical congestion accumulates across
//! iterations, and conflicted nets are ripped up and rerouted until the
//! routing is feasible (every SB/CB wire used by at most one net).
//!
//! # Selective rip-up
//!
//! Iteration 0 routes every net; each later iteration tears out and
//! re-routes **only the nets crossing an overused node**, keeping every
//! conflict-free route — and its occupancy — in place. The
//! [`RouteParams::incremental`] switch gates the occupancy *bookkeeping*
//! only: incremental mode decrements the counts of each ripped net, the
//! `--no-incremental` reference mode recounts from the surviving routes.
//! Counts are integers, so both modes present identical costs to Dijkstra
//! and produce **bit-identical** route trees (`debug_assertions` builds
//! recount and compare every iteration).
//!
//! ```no_run
//! use cascade::apps;
//! use cascade::arch::canal::InterconnectGraph;
//! use cascade::arch::delay::{DelayLib, DelayModelParams};
//! use cascade::arch::params::ArchParams;
//! use cascade::pnr::{build_nets, place, route, PlaceParams, RouteParams};
//!
//! let app = apps::dense::gaussian(64, 64, 1);
//! let arch = ArchParams::paper();
//! let lib = DelayLib::generate(&arch, &DelayModelParams::default());
//! let mut graph = InterconnectGraph::build(&arch);
//! graph.annotate_delays(&lib);
//! let nets = build_nets(&app.dfg, &arch);
//! let placement = place(&app.dfg, &nets, &arch, &PlaceParams::baseline(3));
//! // Incremental occupancy bookkeeping (the default) and the full-recount
//! // reference mode produce identical route trees:
//! let fast =
//!     route(&app.dfg, &nets, &placement, &arch, &graph, &RouteParams::default()).unwrap();
//! let slow = route(
//!     &app.dfg,
//!     &nets,
//!     &placement,
//!     &arch,
//!     &graph,
//!     &RouteParams { incremental: false, ..RouteParams::default() },
//! )
//! .unwrap();
//! assert_eq!(fast.len(), slow.len());
//! ```

use std::collections::{BinaryHeap, HashMap};

use crate::arch::canal::{InterconnectGraph, NodeId as RrgNode, NodeKind};
use crate::arch::params::ArchParams;
use crate::dfg::ir::Dfg;

use super::netlist::Net;
use super::place::Placement;

/// Routing knobs.
#[derive(Debug, Clone)]
pub struct RouteParams {
    pub max_iters: usize,
    pub pres_fac_init: f64,
    pub pres_fac_mult: f64,
    pub hist_fac: f64,
    /// Extra cost per hop (keeps routes from wandering when delays are
    /// small).
    pub hop_cost: f64,
    /// Maintain occupancy incrementally across rip-up iterations instead
    /// of recounting from scratch (default). Results are bit-identical
    /// either way — this is a pure speed switch, installed from
    /// [`crate::pnr::IncrementalCfg`] by the compile driver.
    pub incremental: bool,
}

impl Default for RouteParams {
    fn default() -> Self {
        RouteParams {
            max_iters: 40,
            pres_fac_init: 0.6,
            pres_fac_mult: 1.7,
            hist_fac: 0.4,
            hop_cost: 20.0,
            incremental: true,
        }
    }
}

/// Routing failure.
#[derive(Debug)]
pub enum RouteError {
    /// A sink was unreachable from its source (should not happen on a
    /// connected fabric — indicates a port-mapping bug or zero capacity).
    Unreachable { net: usize, sink: usize },
    /// Congestion did not resolve within `max_iters`. Carries the track
    /// budget so failures on the `explore --tracks` axis are
    /// self-explanatory in sweep reports.
    Unroutable { overused_nodes: usize, iters: usize, tracks: usize },
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::Unreachable { net, sink } => {
                write!(f, "net {net} sink {sink} unreachable")
            }
            RouteError::Unroutable { overused_nodes, iters, tracks } => {
                write!(
                    f,
                    "unroutable: {overused_nodes} overused nodes after {iters} iterations \
                     ({tracks} tracks/side)"
                )
            }
        }
    }
}

impl std::error::Error for RouteError {}

/// The route of one net: one RRG node path per sink (source TileOut ..
/// sink CbIn inclusive). Paths of the same net share prefixes (tree).
#[derive(Debug, Clone)]
pub struct NetRoute {
    pub net: usize,
    pub sink_paths: Vec<Vec<RrgNode>>,
}

impl NetRoute {
    /// All distinct RRG nodes used by this net.
    pub fn nodes(&self) -> impl Iterator<Item = RrgNode> + '_ {
        let mut seen = std::collections::HashSet::new();
        self.sink_paths.iter().flatten().copied().filter(move |&n| seen.insert(n))
    }

    /// Number of switch-box hops on the longest sink path.
    pub fn max_hops(&self, g: &InterconnectGraph) -> usize {
        self.sink_paths
            .iter()
            .map(|p| {
                p.iter()
                    .filter(|&&n| matches!(g.decode(n).kind, NodeKind::SbOut { .. }))
                    .count()
            })
            .max()
            .unwrap_or(0)
    }
}

/// RRG terminal nodes for a net under a placement.
///
/// IO tiles host up to two IO nodes (slots); each slot owns its own
/// TileOut / CbIn port so co-located IO nodes never collide on fabric
/// resources.
pub fn net_terminals(
    net: &Net,
    placement: &Placement,
    graph: &InterconnectGraph,
) -> (RrgNode, Vec<RrgNode>) {
    let src_tile = placement.tile(net.src);
    let src_port = if graph.params.tile_kind(src_tile) == crate::arch::params::TileKind::Io {
        placement.slot[net.src as usize]
    } else {
        net.src_port
    };
    let src = graph.node_id(src_tile, net.layer, NodeKind::TileOut { port: src_port });
    let sinks = net
        .sinks
        .iter()
        .map(|&(node, port)| {
            let tile = placement.tile(node);
            let port = if graph.params.tile_kind(tile) == crate::arch::params::TileKind::Io {
                placement.slot[node as usize]
            } else {
                port
            };
            graph.node_id(tile, net.layer, NodeKind::CbIn { port })
        })
        .collect();
    (src, sinks)
}

/// Route all nets. The interconnect graph must already be delay-annotated.
pub fn route(
    _dfg: &Dfg,
    nets: &[Net],
    placement: &Placement,
    _arch: &ArchParams,
    graph: &InterconnectGraph,
    rp: &RouteParams,
) -> Result<Vec<NetRoute>, RouteError> {
    let nn = graph.num_nodes();
    let mut occ = vec![0u16; nn];
    let mut hist = vec![0f32; nn];
    let mut routes: Vec<NetRoute> =
        nets.iter().map(|n| NetRoute { net: n.id, sink_paths: Vec::new() }).collect();

    // Dijkstra scratch (generation-stamped to avoid O(V) clears).
    let mut dist = vec![f64::INFINITY; nn];
    let mut prev = vec![u32::MAX; nn]; // previous RRG node on best path
    let mut stamp = vec![0u32; nn];
    let mut gen = 0u32;

    // Net order: long (high-fanout) nets first — they are hardest.
    let mut order: Vec<usize> = (0..nets.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(nets[i].fanout()));

    let mut pres_fac = rp.pres_fac_init;
    let mut dirty: Vec<usize> = Vec::new();
    // Kernel work tallies (docs/observability.md): plain locals, handed
    // to the thread's counter sink once on every exit path — routing
    // decisions never depend on them.
    let mut iterations = 0u64;
    let mut nets_ripped = 0u64;
    let mut dijkstra_pops = 0u64;
    let bump_tallies = |iterations: u64, nets_ripped: u64, dijkstra_pops: u64| {
        crate::obs::counters::bump("route_iterations", iterations);
        crate::obs::counters::bump("route_nets_ripped", nets_ripped);
        crate::obs::counters::bump("route_dijkstra_pops", dijkstra_pops);
    };
    for iter in 0..rp.max_iters {
        iterations += 1;
        // Selective rip-up: iteration 0 routes everything; later
        // iterations tear out and re-route only nets crossing an overused
        // node, keeping every conflict-free route (and its occupancy) in
        // place.
        dirty.clear();
        if iter == 0 {
            dirty.extend_from_slice(&order);
        } else {
            for &ni in &order {
                if routes[ni].nodes().any(|nde| occ[nde as usize] > 1) {
                    dirty.push(ni);
                }
            }
            nets_ripped += dirty.len() as u64;
        }
        if rp.incremental {
            // Incremental bookkeeping: subtract each ripped net's usage.
            for &ni in &dirty {
                for nde in routes[ni].nodes() {
                    occ[nde as usize] -= 1;
                }
            }
            for &ni in &dirty {
                routes[ni].sink_paths.clear();
            }
        } else {
            // Full-recount reference (`--no-incremental`): rebuild
            // occupancy from the surviving routes. Integer counts over the
            // same surviving set — bit-identical to the incremental path.
            for &ni in &dirty {
                routes[ni].sink_paths.clear();
            }
            occ.iter_mut().for_each(|o| *o = 0);
            for r in &routes {
                for nde in r.nodes() {
                    occ[nde as usize] += 1;
                }
            }
        }
        #[cfg(debug_assertions)]
        if rp.incremental {
            let mut check = vec![0u16; nn];
            for r in &routes {
                for nde in r.nodes() {
                    check[nde as usize] += 1;
                }
            }
            debug_assert_eq!(occ, check, "incremental occupancy diverged from recount");
        }

        for &ni in &dirty {
            let net = &nets[ni];
            let (src, sink_targets) = net_terminals(net, placement, graph);
            // Tree nodes so far (for multi-sink expansion) mapped to their
            // path-from-source.
            let mut tree_nodes: Vec<RrgNode> = vec![src];
            let mut path_to: HashMap<RrgNode, Vec<RrgNode>> = HashMap::new();
            path_to.insert(src, vec![src]);
            // Net-local usage (a net may reuse its own tree freely).
            let mut in_tree: std::collections::HashSet<RrgNode> =
                tree_nodes.iter().copied().collect();

            // Sinks nearest-first (by Manhattan distance of tiles).
            let mut sink_order: Vec<usize> = (0..sink_targets.len()).collect();
            let src_tile = placement.tile(net.src);
            sink_order.sort_by_key(|&k| {
                placement.tile(net.sinks[k].0).manhattan(src_tile)
            });

            for &k in &sink_order {
                let target = sink_targets[k];
                gen += 1;
                let mut heap: BinaryHeap<std::cmp::Reverse<(u64, RrgNode)>> = BinaryHeap::new();
                for &tn in &tree_nodes {
                    dist[tn as usize] = 0.0;
                    stamp[tn as usize] = gen;
                    prev[tn as usize] = u32::MAX;
                    heap.push(std::cmp::Reverse((0u64, tn)));
                }
                let mut found = false;
                while let Some(std::cmp::Reverse((dbits, u))) = heap.pop() {
                    dijkstra_pops += 1;
                    let d = f64::from_bits(dbits);
                    if stamp[u as usize] == gen && d > dist[u as usize] {
                        continue;
                    }
                    if u == target {
                        found = true;
                        break;
                    }
                    for e in graph.fanout(u) {
                        let v = e.dst;
                        // CbIn nodes are dead ends unless they are the
                        // target (they own a specific tile port).
                        if matches!(graph.decode(v).kind, NodeKind::CbIn { .. }) && v != target {
                            continue;
                        }
                        let vi = v as usize;
                        // Node congestion cost.
                        let over = if in_tree.contains(&v) {
                            0.0
                        } else {
                            let o = occ[vi] as f64;
                            o * pres_fac
                        };
                        let cost = e.delay_ps as f64
                            + rp.hop_cost
                            + (e.delay_ps as f64 + rp.hop_cost) * (hist[vi] as f64 + over);
                        let nd = d + cost;
                        if stamp[vi] != gen || nd < dist[vi] {
                            stamp[vi] = gen;
                            dist[vi] = nd;
                            prev[vi] = u;
                            heap.push(std::cmp::Reverse((nd.to_bits(), v)));
                        }
                    }
                }
                if !found {
                    bump_tallies(iterations, nets_ripped, dijkstra_pops);
                    return Err(RouteError::Unreachable { net: ni, sink: k });
                }
                // Backtrack to a tree node.
                let mut seg = vec![target];
                let mut cur = target;
                while prev[cur as usize] != u32::MAX {
                    cur = prev[cur as usize];
                    seg.push(cur);
                }
                seg.reverse();
                // `cur` is the tree node we joined at.
                let mut full = path_to[&cur].clone();
                full.extend_from_slice(&seg[1..]);
                for &nde in &seg[1..] {
                    if in_tree.insert(nde) {
                        tree_nodes.push(nde);
                    }
                    // Record path prefix for future joins.
                    let idx = full.iter().position(|&x| x == nde).unwrap();
                    path_to.entry(nde).or_insert_with(|| full[..=idx].to_vec());
                }
                routes[ni].sink_paths.resize(sink_targets.len(), Vec::new());
                routes[ni].sink_paths[k] = full;
            }

            // Account usage once per distinct node.
            for nde in routes[ni].nodes() {
                occ[nde as usize] += 1;
            }
        }

        // Check overuse (sources/sinks owned by construction don't
        // conflict; capacity is 1 everywhere).
        let mut overused = 0usize;
        for i in 0..nn {
            if occ[i] > 1 {
                overused += 1;
                hist[i] += (rp.hist_fac * (occ[i] - 1) as f64) as f32;
            }
        }
        if overused == 0 {
            bump_tallies(iterations, nets_ripped, dijkstra_pops);
            return Ok(routes);
        }
        if iter == rp.max_iters - 1 {
            bump_tallies(iterations, nets_ripped, dijkstra_pops);
            return Err(RouteError::Unroutable {
                overused_nodes: overused,
                iters: iter + 1,
                tracks: graph.params.tracks,
            });
        }
        pres_fac *= rp.pres_fac_mult;
    }
    unreachable!()
}

/// Total wirelength (SB hops) of a routing.
pub fn total_hops(routes: &[NetRoute], g: &InterconnectGraph) -> usize {
    routes
        .iter()
        .map(|r| {
            r.nodes()
                .filter(|&n| matches!(g.decode(n).kind, NodeKind::SbOut { .. }))
                .count()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::delay::{DelayLib, DelayModelParams};
    use crate::pnr::netlist::build_nets;
    use crate::pnr::place::{place, PlaceParams};

    fn setup(app: &crate::apps::App) -> (ArchParams, InterconnectGraph, Vec<Net>, Placement) {
        let arch = ArchParams::paper();
        let lib = DelayLib::generate(&arch, &DelayModelParams::default());
        let mut graph = InterconnectGraph::build(&arch);
        graph.annotate_delays(&lib);
        let nets = build_nets(&app.dfg, &arch);
        let placement = place(&app.dfg, &nets, &arch, &PlaceParams::baseline(3));
        (arch, graph, nets, placement)
    }

    #[test]
    fn routes_are_connected_and_legal() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (arch, graph, nets, placement) = setup(&app);
        let routes =
            route(&app.dfg, &nets, &placement, &arch, &graph, &RouteParams::default()).unwrap();
        // Every sink path starts at the net's TileOut and ends at its CbIn,
        // with consecutive nodes connected in the RRG.
        for (ni, r) in routes.iter().enumerate() {
            let (src, sinks) = net_terminals(&nets[ni], &placement, &graph);
            assert_eq!(r.sink_paths.len(), sinks.len());
            for (k, path) in r.sink_paths.iter().enumerate() {
                assert_eq!(path[0], src, "net {ni}");
                assert_eq!(*path.last().unwrap(), sinks[k]);
                for w in path.windows(2) {
                    assert!(
                        graph.fanout(w[0]).iter().any(|e| e.dst == w[1]),
                        "net {ni}: disconnected step {:?} -> {:?}",
                        graph.decode(w[0]),
                        graph.decode(w[1])
                    );
                }
            }
        }
        // No overuse.
        let mut occ = std::collections::HashMap::new();
        for r in &routes {
            for n in r.nodes() {
                *occ.entry(n).or_insert(0u32) += 1;
            }
        }
        for (&n, &c) in &occ {
            assert!(c <= 1, "node {:?} used {c} times", graph.decode(n));
        }
    }

    #[test]
    fn all_dense_apps_route_on_paper_array() {
        for app in crate::apps::small_dense_suite() {
            let (arch, graph, nets, placement) = setup(&app);
            let r = route(&app.dfg, &nets, &placement, &arch, &graph, &RouteParams::default());
            assert!(r.is_ok(), "{} failed: {:?}", app.name, r.err());
        }
    }

    #[test]
    fn sparse_app_routes_with_companions() {
        let app = crate::apps::sparse::vec_elemadd(1024, 0.2);
        let (arch, graph, nets, placement) = setup(&app);
        let routes =
            route(&app.dfg, &nets, &placement, &arch, &graph, &RouteParams::default()).unwrap();
        assert_eq!(routes.len(), nets.len());
        // Ready nets route on the B1 layer.
        use crate::arch::canal::Layer;
        for (ni, net) in nets.iter().enumerate() {
            if net.kind == crate::pnr::netlist::NetKind::Ready {
                for path in &routes[ni].sink_paths {
                    for &n in path.iter() {
                        assert_eq!(graph.decode(n).layer, Layer::B1);
                    }
                }
            }
        }
    }

    #[test]
    fn incremental_routing_matches_full_recount() {
        // The byte-identity contract at the router level: incremental
        // occupancy bookkeeping may never change a single route.
        for app in [
            crate::apps::dense::gaussian(64, 64, 1),
            crate::apps::dense::harris(64, 64, 1),
        ] {
            let (arch, graph, nets, placement) = setup(&app);
            let fast = route(&app.dfg, &nets, &placement, &arch, &graph, &RouteParams::default())
                .unwrap();
            let slow = route(
                &app.dfg,
                &nets,
                &placement,
                &arch,
                &graph,
                &RouteParams { incremental: false, ..RouteParams::default() },
            )
            .unwrap();
            for (f, s) in fast.iter().zip(&slow) {
                assert_eq!(f.net, s.net);
                assert_eq!(f.sink_paths, s.sink_paths, "{}: net {} diverged", app.name, f.net);
            }
        }
    }

    #[test]
    fn hops_correlate_with_distance() {
        // A 2-terminal net between far-apart tiles takes at least the
        // Manhattan distance in SB hops.
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let (arch, graph, nets, placement) = setup(&app);
        let routes =
            route(&app.dfg, &nets, &placement, &arch, &graph, &RouteParams::default()).unwrap();
        for (ni, net) in nets.iter().enumerate() {
            for (k, &(sink, _)) in net.sinks.iter().enumerate() {
                let d = placement.tile(net.src).manhattan(placement.tile(sink));
                let hops = routes[ni].sink_paths[k]
                    .iter()
                    .filter(|&&n| matches!(graph.decode(n).kind, NodeKind::SbOut { .. }))
                    .count();
                assert!(hops >= d, "net {ni} sink {k}: {hops} hops < distance {d}");
            }
        }
    }
}
