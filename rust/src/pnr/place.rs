//! Simulated-annealing detailed placement (paper §V-C).
//!
//! The cost function is the paper's Eq. 1:
//!
//! ```text
//! Cost_net = (HPWL_net + gamma * Area_passthrough)^alpha
//! ```
//!
//! `gamma` penalizes tiles covered by a net's bounding box that are not the
//! net's own terminals (a placement-time proxy for pass-through routing
//! tiles), and `alpha` is the criticality exponent Cascade introduces
//! (similar to timing-driven FPGA placement [17]): with `alpha = 1` the
//! placer minimizes total wirelength (the baseline compiler [16]); with
//! `alpha > 1` long nets are penalized superlinearly, trading average
//! wirelength for a shorter longest route — the knob evaluated in
//! Fig. 7 / Fig. 10 ("placement optimization").

use std::collections::HashMap;

use crate::arch::params::{ArchParams, TileCoord, TileKind};
use crate::dfg::ir::{Dfg, NodeId};
use crate::util::rng::Rng;

use super::netlist::Net;

/// Placement knobs.
#[derive(Debug, Clone)]
pub struct PlaceParams {
    /// Pass-through-area penalty (Eq. 1 gamma).
    pub gamma: f64,
    /// Criticality exponent (Eq. 1 alpha). Baseline 1.0; Cascade's
    /// placement optimization raises it.
    pub alpha: f64,
    /// RNG seed (placement is fully deterministic given the seed).
    pub seed: u64,
    /// Scales the annealing schedule length (1.0 = default effort).
    pub effort: f64,
    /// Optional placement region `(origin, (width, height))` in core-tile
    /// coordinates; IO nodes always live on the IO row within the region's
    /// column span. Used by low unrolling duplication (§V-E).
    pub region: Option<(TileCoord, (usize, usize))>,
}

impl Default for PlaceParams {
    fn default() -> Self {
        PlaceParams { gamma: 0.05, alpha: 1.0, seed: 1, effort: 1.0, region: None }
    }
}

impl PlaceParams {
    /// The baseline compiler's placement (no criticality exponent).
    pub fn baseline(seed: u64) -> PlaceParams {
        PlaceParams { seed, ..Default::default() }
    }

    /// Cascade's placement optimization (§V-C): alpha > 1.
    pub fn cascade(seed: u64) -> PlaceParams {
        PlaceParams { seed, alpha: 1.35, ..Default::default() }
    }

    /// Arbitrary criticality exponent — the sweep hook used by the
    /// `explore` design-space engine's `--alphas` axis.
    pub fn with_alpha(seed: u64, alpha: f64) -> PlaceParams {
        PlaceParams { seed, alpha, ..Default::default() }
    }
}

/// Canonical alpha sweep for design-space exploration (`cascade explore
/// --alphas sweep`): baseline, two intermediate exponents around the
/// paper's operating point, and an aggressive setting.
pub const ALPHA_SWEEP: [f64; 4] = [1.0, 1.2, 1.35, 1.5];

/// A placement: per-node tile and slot (slot is only meaningful on IO
/// tiles, which host up to two IO nodes).
#[derive(Debug, Clone)]
pub struct Placement {
    pub pos: Vec<TileCoord>,
    pub slot: Vec<u8>,
    /// Final Eq. 1 cost.
    pub cost: f64,
}

impl Placement {
    pub fn tile(&self, n: NodeId) -> TileCoord {
        self.pos[n as usize]
    }
}

/// Capacity of a tile for placeable nodes.
fn tile_capacity(kind: TileKind) -> usize {
    match kind {
        TileKind::Pe | TileKind::Mem => 1,
        TileKind::Io => 2,
    }
}

fn build_sites(
    arch: &ArchParams,
    region: &Option<(TileCoord, (usize, usize))>,
) -> HashMap<TileKind, Vec<(TileCoord, u8)>> {
    let mut by_kind: HashMap<TileKind, Vec<(TileCoord, u8)>> = HashMap::new();
    let in_region = |c: TileCoord| -> bool {
        match region {
            None => true,
            Some((o, (w, h))) => {
                let x_ok = c.x >= o.x && (c.x as usize) < o.x as usize + w;
                let y_ok = c.y == 0 || (c.y >= o.y && (c.y as usize) < o.y as usize + h);
                x_ok && y_ok
            }
        }
    };
    for tile in arch.all_tiles() {
        if !in_region(tile) {
            continue;
        }
        let kind = arch.tile_kind(tile);
        for slot in 0..tile_capacity(kind) {
            by_kind.entry(kind).or_default().push((tile, slot as u8));
        }
    }
    by_kind
}

/// Net cost per Eq. 1, computed from terminal positions.
pub fn net_cost(net: &Net, pos: &[TileCoord], gamma: f64, alpha: f64) -> f64 {
    let mut min_x = u16::MAX;
    let mut max_x = 0u16;
    let mut min_y = u16::MAX;
    let mut max_y = 0u16;
    let mut consider = |c: TileCoord| {
        min_x = min_x.min(c.x);
        max_x = max_x.max(c.x);
        min_y = min_y.min(c.y);
        max_y = max_y.max(c.y);
    };
    consider(pos[net.src as usize]);
    for &(s, _) in &net.sinks {
        consider(pos[s as usize]);
    }
    let dx = (max_x - min_x) as f64;
    let dy = (max_y - min_y) as f64;
    let hpwl = dx + dy;
    // Pass-through proxy: bbox tiles not occupied by this net's terminals.
    let terminals = 1 + net.sinks.len();
    let area = ((dx + 1.0) * (dy + 1.0) - terminals as f64).max(0.0);
    (hpwl + gamma * area).powf(alpha)
}

/// Internal mutable placement state. Occupancy is a flat vector indexed
/// by (tile index, slot) — the annealer's innermost data structure.
struct State {
    pos: Vec<TileCoord>,
    slot: Vec<u8>,
    occupancy: Vec<u32>,
    cols: usize,
}

const EMPTY: u32 = u32::MAX;

impl State {
    #[inline]
    fn site(&self, t: TileCoord, s: u8) -> usize {
        (t.y as usize * self.cols + t.x as usize) * 2 + s as usize
    }

    /// Swap `node` with whatever occupies `(t, s)` (possibly nothing).
    /// Returns the occupant (if any) so the move can be undone by calling
    /// `swap` again with the node's old site.
    fn swap(&mut self, node: NodeId, t: TileCoord, s: u8) -> Option<NodeId> {
        let old = (self.pos[node as usize], self.slot[node as usize]);
        let new_site = self.site(t, s);
        let old_site = self.site(old.0, old.1);
        let occ = self.occupancy[new_site];
        let occupant = if occ != EMPTY && occ != node { Some(occ) } else { None };
        self.pos[node as usize] = t;
        self.slot[node as usize] = s;
        self.occupancy[new_site] = node;
        match occupant {
            Some(o) => {
                self.pos[o as usize] = old.0;
                self.slot[o as usize] = old.1;
                self.occupancy[old_site] = o;
            }
            None => {
                if old_site != new_site {
                    self.occupancy[old_site] = EMPTY;
                }
            }
        }
        occupant
    }
}

/// Run simulated-annealing placement. Deterministic given `pp.seed`.
pub fn place(g: &Dfg, nets: &[Net], arch: &ArchParams, pp: &PlaceParams) -> Placement {
    let n = g.nodes.len();
    let sites = build_sites(arch, &pp.region);
    let mut rng = Rng::new(pp.seed);

    // --- Initial placement: round-robin over shuffled legal sites.
    let mut st = State {
        pos: vec![TileCoord::new(0, 0); n],
        slot: vec![0u8; n],
        occupancy: vec![EMPTY; arch.num_tiles() * 2],
        cols: arch.cols,
    };
    {
        let mut per_kind = sites.clone();
        // Fixed kind order: HashMap iteration order would leak into the RNG
        // stream and break determinism.
        for kind in [TileKind::Pe, TileKind::Mem, TileKind::Io] {
            if let Some(v) = per_kind.get_mut(&kind) {
                rng.shuffle(v);
            }
        }
        let mut cursor: HashMap<TileKind, usize> = HashMap::new();
        for i in 0..n {
            let kind = g.nodes[i].tile_kind();
            let list = per_kind
                .get(&kind)
                .unwrap_or_else(|| panic!("no sites of kind {kind:?} in placement region"));
            let c = cursor.entry(kind).or_insert(0);
            assert!(
                *c < list.len(),
                "placement region too small: {kind:?} demand exceeds {} sites",
                list.len()
            );
            let (t, s) = list[*c];
            *c += 1;
            st.pos[i] = t;
            st.slot[i] = s;
            let site = st.site(t, s);
            st.occupancy[site] = i as NodeId;
        }
    }

    // Nets touching each node.
    let mut nets_of: Vec<Vec<usize>> = vec![Vec::new(); n];
    for net in nets {
        nets_of[net.src as usize].push(net.id);
        for &(s, _) in &net.sinks {
            if !nets_of[s as usize].contains(&net.id) {
                nets_of[s as usize].push(net.id);
            }
        }
    }

    let mut net_costs: Vec<f64> =
        nets.iter().map(|nt| net_cost(nt, &st.pos, pp.gamma, pp.alpha)).collect();
    let mut total: f64 = net_costs.iter().sum();

    if n == 0 || nets.is_empty() {
        return Placement { pos: st.pos, slot: st.slot, cost: total };
    }

    let moves_per_temp = (((n * 12) as f64) * pp.effort).ceil().max(1.0) as usize;

    // Estimate T0 from random-move |delta| samples (moves are undone).
    let mut temp = {
        let mut sum = 0.0;
        let mut cnt = 0u32;
        for _ in 0..50 {
            let node = rng.gen_range(n) as NodeId;
            let kind = g.nodes[node as usize].tile_kind();
            let list = &sites[&kind];
            let (t, s) = *rng.choose(list);
            let old = (st.pos[node as usize], st.slot[node as usize]);
            if (t, s) == old {
                continue;
            }
            let occupant = st.swap(node, t, s);
            let mut delta = 0.0;
            for &ni in &nets_of[node as usize] {
                delta += net_cost(&nets[ni], &st.pos, pp.gamma, pp.alpha) - net_costs[ni];
            }
            if let Some(o) = occupant {
                for &ni in &nets_of[o as usize] {
                    if !nets_of[node as usize].contains(&ni) {
                        delta += net_cost(&nets[ni], &st.pos, pp.gamma, pp.alpha) - net_costs[ni];
                    }
                }
            }
            // Undo.
            st.swap(node, old.0, old.1);
            sum += delta.abs();
            cnt += 1;
        }
        (sum / cnt.max(1) as f64).max(1e-3) * 20.0
    };

    let t_final = temp * 1e-4;
    let mut affected: Vec<usize> = Vec::new();
    let mut scratch: Vec<f64> = Vec::new();
    while temp > t_final {
        let mut accepts = 0usize;
        for _ in 0..moves_per_temp {
            let node = rng.gen_range(n) as NodeId;
            let kind = g.nodes[node as usize].tile_kind();
            let list = &sites[&kind];
            let (t, s) = *rng.choose(list);
            let old = (st.pos[node as usize], st.slot[node as usize]);
            if (t, s) == old {
                continue;
            }
            let occupant = st.swap(node, t, s);
            affected.clear();
            affected.extend_from_slice(&nets_of[node as usize]);
            if let Some(o) = occupant {
                for &ni in &nets_of[o as usize] {
                    if !affected.contains(&ni) {
                        affected.push(ni);
                    }
                }
            }
            let before: f64 = affected.iter().map(|&ni| net_costs[ni]).sum();
            scratch.clear();
            let mut after = 0.0;
            for &ni in &affected {
                let c = net_cost(&nets[ni], &st.pos, pp.gamma, pp.alpha);
                scratch.push(c);
                after += c;
            }
            let delta = after - before;
            if delta < 0.0 || rng.gen_f64() < (-delta / temp).exp() {
                for (k, &ni) in affected.iter().enumerate() {
                    net_costs[ni] = scratch[k];
                }
                total += delta;
                accepts += 1;
            } else {
                st.swap(node, old.0, old.1);
            }
        }
        if accepts == 0 {
            break;
        }
        temp *= 0.9;
    }

    Placement { pos: st.pos, slot: st.slot, cost: total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::pnr::netlist::build_nets;

    fn place_app(app: &crate::apps::App, pp: &PlaceParams) -> (Placement, Vec<Net>) {
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let p = place(&app.dfg, &nets, &arch, pp);
        (p, nets)
    }

    #[test]
    fn placement_is_legal() {
        let app = apps::dense::gaussian(64, 64, 2);
        let arch = ArchParams::paper();
        let (p, _) = place_app(&app, &PlaceParams::baseline(3));
        for (i, node) in app.dfg.nodes.iter().enumerate() {
            assert_eq!(arch.tile_kind(p.pos[i]), node.tile_kind(), "node {i}");
        }
        let mut seen = std::collections::HashSet::new();
        for i in 0..app.dfg.nodes.len() {
            assert!(seen.insert((p.pos[i], p.slot[i])), "overlap at node {i}");
        }
    }

    #[test]
    fn placement_deterministic() {
        let app = apps::dense::gaussian(64, 64, 1);
        let (p1, _) = place_app(&app, &PlaceParams::baseline(7));
        let (p2, _) = place_app(&app, &PlaceParams::baseline(7));
        assert_eq!(p1.pos, p2.pos);
        assert_eq!(p1.cost, p2.cost);
    }

    #[test]
    fn cached_cost_matches_recompute() {
        let app = apps::dense::unsharp(64, 64, 1);
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let pp = PlaceParams::baseline(9);
        let p = place(&app.dfg, &nets, &arch, &pp);
        let recomputed: f64 =
            nets.iter().map(|nt| net_cost(nt, &p.pos, pp.gamma, pp.alpha)).sum();
        assert!(
            (p.cost - recomputed).abs() < 1e-6 * recomputed.max(1.0),
            "cached {} vs recomputed {}",
            p.cost,
            recomputed
        );
    }

    #[test]
    fn annealing_beats_quick_anneal() {
        let app = apps::dense::harris(64, 64, 1);
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let p0 = place(
            &app.dfg,
            &nets,
            &arch,
            &PlaceParams { effort: 0.01, ..PlaceParams::baseline(5) },
        );
        let p1 = place(&app.dfg, &nets, &arch, &PlaceParams::baseline(5));
        assert!(p1.cost < p0.cost, "SA {} vs quick {}", p1.cost, p0.cost);
    }

    #[test]
    fn alpha_sweep_hook_is_deterministic_and_ordered() {
        // The `explore` axis hook: every canonical sweep value places
        // deterministically, and raising alpha never materially lengthens
        // the longest net (it superlinearly penalizes long nets).
        let app = apps::dense::gaussian(64, 64, 1);
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let longest = |p: &Placement| -> f64 {
            nets.iter().map(|nt| net_cost(nt, &p.pos, 0.0, 1.0)).fold(0.0, f64::max)
        };
        let mut base_longest = None;
        for &alpha in &ALPHA_SWEEP {
            let pp = PlaceParams::with_alpha(21, alpha);
            let p1 = place(&app.dfg, &nets, &arch, &pp);
            let p2 = place(&app.dfg, &nets, &arch, &pp);
            assert_eq!(p1.pos, p2.pos, "alpha {alpha} not deterministic");
            let b = *base_longest.get_or_insert(longest(&p1));
            assert!(
                longest(&p1) <= b * 1.25,
                "alpha {alpha} lengthened the longest net: {} vs {}",
                longest(&p1),
                b
            );
        }
    }

    #[test]
    fn alpha_shrinks_longest_net() {
        let app = apps::dense::resnet_conv5x();
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let base = place(&app.dfg, &nets, &arch, &PlaceParams::baseline(11));
        let casc = place(&app.dfg, &nets, &arch, &PlaceParams::cascade(11));
        let longest = |p: &Placement| -> f64 {
            nets.iter().map(|nt| net_cost(nt, &p.pos, 0.0, 1.0)).fold(0.0, f64::max)
        };
        assert!(
            longest(&casc) <= longest(&base) * 1.1,
            "alpha should not materially lengthen the longest net: {} vs {}",
            longest(&casc),
            longest(&base)
        );
    }

    #[test]
    fn region_constraint_respected() {
        let app = apps::dense::gaussian(64, 64, 1);
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let region = Some((TileCoord::new(0, 1), (8, 16)));
        let p = place(
            &app.dfg,
            &nets,
            &arch,
            &PlaceParams { region, ..PlaceParams::baseline(2) },
        );
        for i in 0..app.dfg.nodes.len() {
            assert!(p.pos[i].x < 8, "node {i} escaped region: {:?}", p.pos[i]);
        }
    }

    #[test]
    #[should_panic(expected = "placement region")]
    fn too_small_region_panics() {
        let app = apps::dense::harris(512, 512, 4);
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let region = Some((TileCoord::new(0, 1), (2, 2)));
        let _ = place(
            &app.dfg,
            &nets,
            &arch,
            &PlaceParams { region, ..PlaceParams::baseline(2) },
        );
    }
}
