//! Simulated-annealing detailed placement (paper §V-C).
//!
//! The cost function is the paper's Eq. 1:
//!
//! ```text
//! Cost_net = (HPWL_net + gamma * Area_passthrough)^alpha
//! ```
//!
//! `gamma` penalizes tiles covered by a net's bounding box that are not the
//! net's own terminals (a placement-time proxy for pass-through routing
//! tiles), and `alpha` is the criticality exponent Cascade introduces
//! (similar to timing-driven FPGA placement [17]): with `alpha = 1` the
//! placer minimizes total wirelength (the baseline compiler [16]); with
//! `alpha > 1` long nets are penalized superlinearly, trading average
//! wirelength for a shorter longest route — the knob evaluated in
//! Fig. 7 / Fig. 10 ("placement optimization").
//!
//! # Incremental delta-cost moves
//!
//! The annealer keeps a per-net cost table and, per move, recomputes only
//! the nets touching the moved cell(s). In incremental mode (the default,
//! [`PlaceParams::incremental`]) each net additionally carries its current
//! bounding box with *occurrence counts* on every boundary, so a move
//! updates the box in O(1) instead of rescanning the net's terminals; the
//! rare move that empties a boundary count falls back to an exact rescan.
//! Because coordinates are integers, the maintained box is exactly the
//! scanned box, both modes evaluate Eq. 1 through the same function on the
//! same inputs, and the accept/reject decision sequence — and therefore
//! the final placement — is **bit-identical** with incremental mode on or
//! off. `debug_assertions` builds verify the box against a from-scratch
//! rescan on every staged move, and the reported [`Placement::cost`] is
//! always recomputed fresh from final positions.
//!
//! ```no_run
//! use cascade::apps;
//! use cascade::arch::params::ArchParams;
//! use cascade::pnr::{build_nets, place, PlaceParams};
//!
//! let app = apps::dense::gaussian(64, 64, 1);
//! let arch = ArchParams::paper();
//! let nets = build_nets(&app.dfg, &arch);
//! // Incremental delta-cost annealing (the default) and a from-scratch
//! // run produce bit-identical placements:
//! let fast = place(&app.dfg, &nets, &arch, &PlaceParams::baseline(3));
//! let slow = place(
//!     &app.dfg,
//!     &nets,
//!     &arch,
//!     &PlaceParams { incremental: false, ..PlaceParams::baseline(3) },
//! );
//! assert_eq!(fast.pos, slow.pos);
//! assert_eq!(fast.cost.to_bits(), slow.cost.to_bits());
//! ```

use std::collections::HashMap;

use crate::arch::params::{ArchParams, TileCoord, TileKind};
use crate::dfg::ir::{Dfg, NodeId};
use crate::util::rng::Rng;

use super::netlist::Net;

/// Placement knobs.
#[derive(Debug, Clone)]
pub struct PlaceParams {
    /// Pass-through-area penalty (Eq. 1 gamma).
    pub gamma: f64,
    /// Criticality exponent (Eq. 1 alpha). Baseline 1.0; Cascade's
    /// placement optimization raises it.
    pub alpha: f64,
    /// RNG seed (placement is fully deterministic given the seed).
    pub seed: u64,
    /// Scales the annealing schedule length (1.0 = default effort).
    pub effort: f64,
    /// Optional placement region `(origin, (width, height))` in core-tile
    /// coordinates; IO nodes always live on the IO row within the region's
    /// column span. Used by low unrolling duplication (§V-E).
    pub region: Option<(TileCoord, (usize, usize))>,
    /// Maintain per-net bounding boxes incrementally across moves instead
    /// of rescanning every affected net's terminals (default). Results are
    /// bit-identical either way — this is a pure speed switch, installed
    /// from [`crate::pnr::IncrementalCfg`] by the compile driver.
    pub incremental: bool,
}

impl Default for PlaceParams {
    fn default() -> Self {
        PlaceParams {
            gamma: 0.05,
            alpha: 1.0,
            seed: 1,
            effort: 1.0,
            region: None,
            incremental: true,
        }
    }
}

impl PlaceParams {
    /// The baseline compiler's placement (no criticality exponent).
    pub fn baseline(seed: u64) -> PlaceParams {
        PlaceParams { seed, ..Default::default() }
    }

    /// Cascade's placement optimization (§V-C): alpha > 1.
    pub fn cascade(seed: u64) -> PlaceParams {
        PlaceParams { seed, alpha: 1.35, ..Default::default() }
    }

    /// Arbitrary criticality exponent — the sweep hook used by the
    /// `explore` design-space engine's `--alphas` axis.
    pub fn with_alpha(seed: u64, alpha: f64) -> PlaceParams {
        PlaceParams { seed, alpha, ..Default::default() }
    }
}

/// Canonical alpha sweep for design-space exploration (`cascade explore
/// --alphas sweep`): baseline, two intermediate exponents around the
/// paper's operating point, and an aggressive setting.
pub const ALPHA_SWEEP: [f64; 4] = [1.0, 1.2, 1.35, 1.5];

/// A placement: per-node tile and slot (slot is only meaningful on IO
/// tiles, which host up to two IO nodes).
#[derive(Debug, Clone)]
pub struct Placement {
    pub pos: Vec<TileCoord>,
    pub slot: Vec<u8>,
    /// Final Eq. 1 cost.
    pub cost: f64,
}

impl Placement {
    pub fn tile(&self, n: NodeId) -> TileCoord {
        self.pos[n as usize]
    }
}

/// Capacity of a tile for placeable nodes.
fn tile_capacity(kind: TileKind) -> usize {
    match kind {
        TileKind::Pe | TileKind::Mem => 1,
        TileKind::Io => 2,
    }
}

fn build_sites(
    arch: &ArchParams,
    region: &Option<(TileCoord, (usize, usize))>,
) -> HashMap<TileKind, Vec<(TileCoord, u8)>> {
    let mut by_kind: HashMap<TileKind, Vec<(TileCoord, u8)>> = HashMap::new();
    let in_region = |c: TileCoord| -> bool {
        match region {
            None => true,
            Some((o, (w, h))) => {
                let x_ok = c.x >= o.x && (c.x as usize) < o.x as usize + w;
                let y_ok = c.y == 0 || (c.y >= o.y && (c.y as usize) < o.y as usize + h);
                x_ok && y_ok
            }
        }
    };
    for tile in arch.all_tiles() {
        if !in_region(tile) {
            continue;
        }
        let kind = arch.tile_kind(tile);
        for slot in 0..tile_capacity(kind) {
            by_kind.entry(kind).or_default().push((tile, slot as u8));
        }
    }
    by_kind
}

/// A net's terminal bounding box with *occurrence counts* on each boundary
/// (VPR-style). The counts make single-terminal moves O(1): removing a
/// terminal that is not the last occurrence of a boundary coordinate keeps
/// the box valid without a rescan, and the rare move that empties a
/// boundary count signals the caller to rescan. Coordinates are integers,
/// so the maintained box is *exactly* the scanned box — no drift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct NetBox {
    min_x: u16,
    max_x: u16,
    min_y: u16,
    max_y: u16,
    n_min_x: u32,
    n_max_x: u32,
    n_min_y: u32,
    n_max_y: u32,
}

impl NetBox {
    /// Scan all of a net's terminals from scratch.
    pub(crate) fn scan(net: &Net, pos: &[TileCoord]) -> NetBox {
        let mut bx = NetBox {
            min_x: u16::MAX,
            max_x: 0,
            min_y: u16::MAX,
            max_y: 0,
            n_min_x: 0,
            n_max_x: 0,
            n_min_y: 0,
            n_max_y: 0,
        };
        bx.add(pos[net.src as usize], 1);
        for &(s, _) in &net.sinks {
            bx.add(pos[s as usize], 1);
        }
        bx
    }

    /// Account for `mult` terminal occurrences at `c`.
    pub(crate) fn add(&mut self, c: TileCoord, mult: u32) {
        if c.x < self.min_x {
            self.min_x = c.x;
            self.n_min_x = mult;
        } else if c.x == self.min_x {
            self.n_min_x += mult;
        }
        if c.x > self.max_x {
            self.max_x = c.x;
            self.n_max_x = mult;
        } else if c.x == self.max_x {
            self.n_max_x += mult;
        }
        if c.y < self.min_y {
            self.min_y = c.y;
            self.n_min_y = mult;
        } else if c.y == self.min_y {
            self.n_min_y += mult;
        }
        if c.y > self.max_y {
            self.max_y = c.y;
            self.n_max_y = mult;
        } else if c.y == self.max_y {
            self.n_max_y += mult;
        }
    }

    /// Remove `mult` terminal occurrences at `c`. Returns `false` when a
    /// boundary count hits zero — the box is then stale and the caller
    /// must rescan (the shrink direction is unknowable without one).
    #[must_use]
    pub(crate) fn remove(&mut self, c: TileCoord, mult: u32) -> bool {
        if c.x == self.min_x {
            self.n_min_x -= mult;
            if self.n_min_x == 0 {
                return false;
            }
        }
        if c.x == self.max_x {
            self.n_max_x -= mult;
            if self.n_max_x == 0 {
                return false;
            }
        }
        if c.y == self.min_y {
            self.n_min_y -= mult;
            if self.n_min_y == 0 {
                return false;
            }
        }
        if c.y == self.max_y {
            self.n_max_y -= mult;
            if self.n_max_y == 0 {
                return false;
            }
        }
        true
    }
}

/// Eq. 1 evaluated on a bounding box. Both the from-scratch and the
/// incremental paths funnel through this one function, so equal boxes
/// yield bit-identical costs by construction.
pub(crate) fn cost_from_box(bx: &NetBox, terminals: usize, gamma: f64, alpha: f64) -> f64 {
    let dx = (bx.max_x - bx.min_x) as f64;
    let dy = (bx.max_y - bx.min_y) as f64;
    let hpwl = dx + dy;
    // Pass-through proxy: bbox tiles not occupied by this net's terminals.
    let area = ((dx + 1.0) * (dy + 1.0) - terminals as f64).max(0.0);
    (hpwl + gamma * area).powf(alpha)
}

/// Net cost per Eq. 1, computed from terminal positions.
pub fn net_cost(net: &Net, pos: &[TileCoord], gamma: f64, alpha: f64) -> f64 {
    cost_from_box(&NetBox::scan(net, pos), 1 + net.sinks.len(), gamma, alpha)
}

/// Internal mutable placement state. Occupancy is a flat vector indexed
/// by (tile index, slot) — the annealer's innermost data structure.
struct State {
    pos: Vec<TileCoord>,
    slot: Vec<u8>,
    occupancy: Vec<u32>,
    cols: usize,
}

const EMPTY: u32 = u32::MAX;

impl State {
    #[inline]
    fn site(&self, t: TileCoord, s: u8) -> usize {
        (t.y as usize * self.cols + t.x as usize) * 2 + s as usize
    }

    /// Swap `node` with whatever occupies `(t, s)` (possibly nothing).
    /// Returns the occupant (if any) so the move can be undone by calling
    /// `swap` again with the node's old site.
    fn swap(&mut self, node: NodeId, t: TileCoord, s: u8) -> Option<NodeId> {
        let old = (self.pos[node as usize], self.slot[node as usize]);
        let new_site = self.site(t, s);
        let old_site = self.site(old.0, old.1);
        let occ = self.occupancy[new_site];
        let occupant = if occ != EMPTY && occ != node { Some(occ) } else { None };
        self.pos[node as usize] = t;
        self.slot[node as usize] = s;
        self.occupancy[new_site] = node;
        match occupant {
            Some(o) => {
                self.pos[o as usize] = old.0;
                self.slot[o as usize] = old.1;
                self.occupancy[old_site] = o;
            }
            None => {
                if old_site != new_site {
                    self.occupancy[old_site] = EMPTY;
                }
            }
        }
        occupant
    }
}

/// Run simulated-annealing placement. Deterministic given `pp.seed`.
pub fn place(g: &Dfg, nets: &[Net], arch: &ArchParams, pp: &PlaceParams) -> Placement {
    let n = g.nodes.len();
    let sites = build_sites(arch, &pp.region);
    let mut rng = Rng::new(pp.seed);

    // --- Initial placement: round-robin over shuffled legal sites.
    let mut st = State {
        pos: vec![TileCoord::new(0, 0); n],
        slot: vec![0u8; n],
        occupancy: vec![EMPTY; arch.num_tiles() * 2],
        cols: arch.cols,
    };
    {
        let mut per_kind = sites.clone();
        // Fixed kind order: HashMap iteration order would leak into the RNG
        // stream and break determinism.
        for kind in [TileKind::Pe, TileKind::Mem, TileKind::Io] {
            if let Some(v) = per_kind.get_mut(&kind) {
                rng.shuffle(v);
            }
        }
        let mut cursor: HashMap<TileKind, usize> = HashMap::new();
        for i in 0..n {
            let kind = g.nodes[i].tile_kind();
            let list = per_kind
                .get(&kind)
                .unwrap_or_else(|| panic!("no sites of kind {kind:?} in placement region"));
            let c = cursor.entry(kind).or_insert(0);
            assert!(
                *c < list.len(),
                "placement region too small: {kind:?} demand exceeds {} sites",
                list.len()
            );
            let (t, s) = list[*c];
            *c += 1;
            st.pos[i] = t;
            st.slot[i] = s;
            let site = st.site(t, s);
            st.occupancy[site] = i as NodeId;
        }
    }

    // Nets touching each node, with the node's terminal multiplicity in
    // each net (a node can be both src and sink, or sink several times) —
    // the multiplicity is what lets incremental mode update a box without
    // rescanning the net.
    let mut nets_of: Vec<Vec<(usize, u32)>> = vec![Vec::new(); n];
    for net in nets {
        let mut bump = |node: NodeId| {
            let v = &mut nets_of[node as usize];
            match v.iter_mut().find(|(id, _)| *id == net.id) {
                Some((_, m)) => *m += 1,
                None => v.push((net.id, 1)),
            }
        };
        bump(net.src);
        for &(s, _) in &net.sinks {
            bump(s);
        }
    }

    let mut net_costs: Vec<f64> =
        nets.iter().map(|nt| net_cost(nt, &st.pos, pp.gamma, pp.alpha)).collect();

    if n == 0 || nets.is_empty() {
        let cost = net_costs.iter().sum();
        return Placement { pos: st.pos, slot: st.slot, cost };
    }

    let moves_per_temp = (((n * 12) as f64) * pp.effort).ceil().max(1.0) as usize;

    // Estimate T0 from random-move |delta| samples (moves are undone).
    let mut temp = {
        let mut sum = 0.0;
        let mut cnt = 0u32;
        for _ in 0..50 {
            let node = rng.gen_range(n) as NodeId;
            let kind = g.nodes[node as usize].tile_kind();
            let list = &sites[&kind];
            let (t, s) = *rng.choose(list);
            let old = (st.pos[node as usize], st.slot[node as usize]);
            if (t, s) == old {
                continue;
            }
            let occupant = st.swap(node, t, s);
            let mut delta = 0.0;
            for &(ni, _) in &nets_of[node as usize] {
                delta += net_cost(&nets[ni], &st.pos, pp.gamma, pp.alpha) - net_costs[ni];
            }
            if let Some(o) = occupant {
                for &(ni, _) in &nets_of[o as usize] {
                    if !nets_of[node as usize].iter().any(|&(id, _)| id == ni) {
                        delta += net_cost(&nets[ni], &st.pos, pp.gamma, pp.alpha) - net_costs[ni];
                    }
                }
            }
            // Undo.
            st.swap(node, old.0, old.1);
            sum += delta.abs();
            cnt += 1;
        }
        (sum / cnt.max(1) as f64).max(1e-3) * 20.0
    };

    let t_final = temp * 1e-4;
    // Committed per-net boxes (incremental mode only): maintained across
    // accepted moves so a move costs O(affected nets), not O(terminals).
    let mut boxes: Vec<NetBox> = if pp.incremental {
        nets.iter().map(|nt| NetBox::scan(nt, &st.pos)).collect()
    } else {
        Vec::new()
    };
    let mut affected: Vec<usize> = Vec::new();
    let mut scratch: Vec<f64> = Vec::new();
    let mut scratch_boxes: Vec<NetBox> = Vec::new();
    let mult_of = |v: &[(usize, u32)], ni: usize| -> Option<u32> {
        v.iter().find(|&&(id, _)| id == ni).map(|&(_, m)| m)
    };
    // Kernel work tallies (docs/observability.md): plain locals either
    // way, handed to the thread's counter sink once at the end — the
    // annealer's decisions never depend on them.
    let mut moves_proposed = 0u64;
    let mut moves_accepted = 0u64;
    let mut box_rescans = 0u64;
    while temp > t_final {
        let mut accepts = 0usize;
        for _ in 0..moves_per_temp {
            let node = rng.gen_range(n) as NodeId;
            let kind = g.nodes[node as usize].tile_kind();
            let list = &sites[&kind];
            let (t, s) = *rng.choose(list);
            let old = (st.pos[node as usize], st.slot[node as usize]);
            if (t, s) == old {
                continue;
            }
            moves_proposed += 1;
            let occupant = st.swap(node, t, s);
            affected.clear();
            for &(ni, _) in &nets_of[node as usize] {
                affected.push(ni);
            }
            if let Some(o) = occupant {
                for &(ni, _) in &nets_of[o as usize] {
                    if !affected.contains(&ni) {
                        affected.push(ni);
                    }
                }
            }
            let before: f64 = affected.iter().map(|&ni| net_costs[ni]).sum();
            scratch.clear();
            scratch_boxes.clear();
            let mut after = 0.0;
            for &ni in &affected {
                let c = if pp.incremental {
                    // Stage a candidate box: node moved old.0 -> t, the
                    // displaced occupant (if any) moved t -> old.0. If a
                    // removal empties a boundary count, fall back to an
                    // exact rescan of the post-swap positions.
                    let nm = mult_of(&nets_of[node as usize], ni);
                    let om = occupant.and_then(|o| mult_of(&nets_of[o as usize], ni));
                    let mut bx = boxes[ni];
                    let mut exact = true;
                    if let Some(m) = nm {
                        exact = bx.remove(old.0, m);
                    }
                    if exact {
                        if let Some(m) = om {
                            exact = bx.remove(t, m);
                        }
                    }
                    if exact {
                        if let Some(m) = nm {
                            bx.add(t, m);
                        }
                        if let Some(m) = om {
                            bx.add(old.0, m);
                        }
                    } else {
                        box_rescans += 1;
                        bx = NetBox::scan(&nets[ni], &st.pos);
                    }
                    debug_assert_eq!(
                        bx,
                        NetBox::scan(&nets[ni], &st.pos),
                        "incremental box diverged from rescan (net {ni})"
                    );
                    scratch_boxes.push(bx);
                    cost_from_box(&bx, 1 + nets[ni].sinks.len(), pp.gamma, pp.alpha)
                } else {
                    net_cost(&nets[ni], &st.pos, pp.gamma, pp.alpha)
                };
                scratch.push(c);
                after += c;
            }
            let delta = after - before;
            if delta < 0.0 || rng.gen_f64() < (-delta / temp).exp() {
                for (k, &ni) in affected.iter().enumerate() {
                    net_costs[ni] = scratch[k];
                    if pp.incremental {
                        boxes[ni] = scratch_boxes[k];
                    }
                }
                accepts += 1;
                moves_accepted += 1;
            } else {
                st.swap(node, old.0, old.1);
            }
        }
        if accepts == 0 {
            break;
        }
        temp *= 0.9;
    }
    crate::obs::counters::bump("place_moves_proposed", moves_proposed);
    crate::obs::counters::bump("place_moves_accepted", moves_accepted);
    crate::obs::counters::bump("place_moves_rejected", moves_proposed - moves_accepted);
    crate::obs::counters::bump("place_box_rescans", box_rescans);

    // Report the cost recomputed fresh from final positions in both modes
    // (not the accumulated sum of per-move deltas), so `Placement::cost`
    // exactly equals a from-scratch recompute regardless of `incremental`.
    let cost: f64 = nets.iter().map(|nt| net_cost(nt, &st.pos, pp.gamma, pp.alpha)).sum();
    Placement { pos: st.pos, slot: st.slot, cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::pnr::netlist::build_nets;

    fn place_app(app: &crate::apps::App, pp: &PlaceParams) -> (Placement, Vec<Net>) {
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let p = place(&app.dfg, &nets, &arch, pp);
        (p, nets)
    }

    #[test]
    fn placement_is_legal() {
        let app = apps::dense::gaussian(64, 64, 2);
        let arch = ArchParams::paper();
        let (p, _) = place_app(&app, &PlaceParams::baseline(3));
        for (i, node) in app.dfg.nodes.iter().enumerate() {
            assert_eq!(arch.tile_kind(p.pos[i]), node.tile_kind(), "node {i}");
        }
        let mut seen = std::collections::HashSet::new();
        for i in 0..app.dfg.nodes.len() {
            assert!(seen.insert((p.pos[i], p.slot[i])), "overlap at node {i}");
        }
    }

    #[test]
    fn placement_deterministic() {
        let app = apps::dense::gaussian(64, 64, 1);
        let (p1, _) = place_app(&app, &PlaceParams::baseline(7));
        let (p2, _) = place_app(&app, &PlaceParams::baseline(7));
        assert_eq!(p1.pos, p2.pos);
        assert_eq!(p1.cost, p2.cost);
    }

    #[test]
    fn cached_cost_matches_recompute() {
        // Exact, not approximate: the reported cost is recomputed fresh
        // from final positions, never the accumulated sum of move deltas.
        let app = apps::dense::unsharp(64, 64, 1);
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let pp = PlaceParams::baseline(9);
        let p = place(&app.dfg, &nets, &arch, &pp);
        let recomputed: f64 =
            nets.iter().map(|nt| net_cost(nt, &p.pos, pp.gamma, pp.alpha)).sum();
        assert_eq!(
            p.cost.to_bits(),
            recomputed.to_bits(),
            "cached {} vs recomputed {}",
            p.cost,
            recomputed
        );
    }

    #[test]
    fn incremental_placement_matches_scratch_placement() {
        // The byte-identity contract at the placer level: incremental
        // bounding-box maintenance may never change a single decision.
        let app = apps::dense::gaussian(64, 64, 2);
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        for seed in [1u64, 21] {
            let inc = place(&app.dfg, &nets, &arch, &PlaceParams::cascade(seed));
            let scr = place(
                &app.dfg,
                &nets,
                &arch,
                &PlaceParams { incremental: false, ..PlaceParams::cascade(seed) },
            );
            assert_eq!(inc.pos, scr.pos, "seed {seed}: positions diverged");
            assert_eq!(inc.slot, scr.slot, "seed {seed}: slots diverged");
            assert_eq!(
                inc.cost.to_bits(),
                scr.cost.to_bits(),
                "seed {seed}: cost diverged ({} vs {})",
                inc.cost,
                scr.cost
            );
        }
    }

    #[test]
    fn net_box_updates_match_rescan_under_random_moves() {
        // Property test: N random single-node moves, each staged
        // incrementally and randomly accepted or rejected, leave every
        // maintained box (and hence every cached cost) equal to a
        // from-scratch rescan.
        let app = apps::dense::harris(64, 64, 1);
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let p = place(
            &app.dfg,
            &nets,
            &arch,
            &PlaceParams { effort: 0.02, ..PlaceParams::baseline(13) },
        );
        let mut pos = p.pos.clone();
        let n = app.dfg.nodes.len();
        let mut nets_of: Vec<Vec<(usize, u32)>> = vec![Vec::new(); n];
        for net in &nets {
            let mut bump = |node: NodeId| {
                let v = &mut nets_of[node as usize];
                match v.iter_mut().find(|(id, _)| *id == net.id) {
                    Some((_, m)) => *m += 1,
                    None => v.push((net.id, 1)),
                }
            };
            bump(net.src);
            for &(s, _) in &net.sinks {
                bump(s);
            }
        }
        let mut boxes: Vec<NetBox> = nets.iter().map(|nt| NetBox::scan(nt, &pos)).collect();
        let tiles: Vec<TileCoord> = arch.all_tiles().collect();
        let mut rng = Rng::new(99);
        for step in 0..400 {
            let node = rng.gen_range(n);
            let old = pos[node];
            let newc = *rng.choose(&tiles);
            pos[node] = newc;
            let mut staged: Vec<(usize, NetBox)> = Vec::new();
            for &(ni, m) in &nets_of[node] {
                let mut bx = boxes[ni];
                if bx.remove(old, m) {
                    bx.add(newc, m);
                } else {
                    bx = NetBox::scan(&nets[ni], &pos);
                }
                assert_eq!(
                    bx,
                    NetBox::scan(&nets[ni], &pos),
                    "step {step}, net {ni}: incremental box diverged"
                );
                staged.push((ni, bx));
            }
            if rng.gen_f64() < 0.5 {
                for (ni, bx) in staged {
                    boxes[ni] = bx;
                }
            } else {
                pos[node] = old; // rejected move: committed table untouched
            }
        }
        for (ni, nt) in nets.iter().enumerate() {
            assert_eq!(boxes[ni], NetBox::scan(nt, &pos), "net {ni}: final box stale");
            assert_eq!(
                cost_from_box(&boxes[ni], 1 + nt.sinks.len(), 0.05, 1.35).to_bits(),
                net_cost(nt, &pos, 0.05, 1.35).to_bits(),
                "net {ni}: final cost stale"
            );
        }
    }

    #[test]
    fn annealing_beats_quick_anneal() {
        let app = apps::dense::harris(64, 64, 1);
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let p0 = place(
            &app.dfg,
            &nets,
            &arch,
            &PlaceParams { effort: 0.01, ..PlaceParams::baseline(5) },
        );
        let p1 = place(&app.dfg, &nets, &arch, &PlaceParams::baseline(5));
        assert!(p1.cost < p0.cost, "SA {} vs quick {}", p1.cost, p0.cost);
    }

    #[test]
    fn alpha_sweep_hook_is_deterministic_and_ordered() {
        // The `explore` axis hook: every canonical sweep value places
        // deterministically, and raising alpha never materially lengthens
        // the longest net (it superlinearly penalizes long nets).
        let app = apps::dense::gaussian(64, 64, 1);
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let longest = |p: &Placement| -> f64 {
            nets.iter().map(|nt| net_cost(nt, &p.pos, 0.0, 1.0)).fold(0.0, f64::max)
        };
        let mut base_longest = None;
        for &alpha in &ALPHA_SWEEP {
            let pp = PlaceParams::with_alpha(21, alpha);
            let p1 = place(&app.dfg, &nets, &arch, &pp);
            let p2 = place(&app.dfg, &nets, &arch, &pp);
            assert_eq!(p1.pos, p2.pos, "alpha {alpha} not deterministic");
            let b = *base_longest.get_or_insert(longest(&p1));
            assert!(
                longest(&p1) <= b * 1.25,
                "alpha {alpha} lengthened the longest net: {} vs {}",
                longest(&p1),
                b
            );
        }
    }

    #[test]
    fn alpha_shrinks_longest_net() {
        let app = apps::dense::resnet_conv5x();
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let base = place(&app.dfg, &nets, &arch, &PlaceParams::baseline(11));
        let casc = place(&app.dfg, &nets, &arch, &PlaceParams::cascade(11));
        let longest = |p: &Placement| -> f64 {
            nets.iter().map(|nt| net_cost(nt, &p.pos, 0.0, 1.0)).fold(0.0, f64::max)
        };
        assert!(
            longest(&casc) <= longest(&base) * 1.1,
            "alpha should not materially lengthen the longest net: {} vs {}",
            longest(&casc),
            longest(&base)
        );
    }

    #[test]
    fn region_constraint_respected() {
        let app = apps::dense::gaussian(64, 64, 1);
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let region = Some((TileCoord::new(0, 1), (8, 16)));
        let p = place(
            &app.dfg,
            &nets,
            &arch,
            &PlaceParams { region, ..PlaceParams::baseline(2) },
        );
        for i in 0..app.dfg.nodes.len() {
            assert!(p.pos[i].x < 8, "node {i} escaped region: {:?}", p.pos[i]);
        }
    }

    #[test]
    #[should_panic(expected = "placement region")]
    fn too_small_region_panics() {
        let app = apps::dense::harris(512, 512, 4);
        let arch = ArchParams::paper();
        let nets = build_nets(&app.dfg, &arch);
        let region = Some((TileCoord::new(0, 1), (2, 2)));
        let _ = place(
            &app.dfg,
            &nets,
            &arch,
            &PlaceParams { region, ..PlaceParams::baseline(2) },
        );
    }
}
