//! Place and route.
//!
//! * [`netlist`] — net extraction from the DFG, including the sparse
//!   valid/ready companion nets (§VII: "If a piece of data is routed from
//!   Tile A to Tile B, a valid signal will be routed in the exact same way
//!   ... A ready signal will be routed in the same way but in the opposite
//!   direction") and the flush broadcast net (§VI), which is omitted when
//!   the architecture hardens it.
//! * [`place`] — simulated-annealing detailed placement with the paper's
//!   Eq. 1 cost: `Cost_net = (HPWL_net + gamma * Area_passthrough)^alpha`,
//!   where `alpha` is Cascade's criticality exponent (§V-C).
//! * [`route`] — PathFinder-style negotiated-congestion routing over the
//!   Canal interconnect graph, producing per-net route trees.
//! * [`design`] — the routed design: placement + route trees + enabled
//!   pipelining registers; the object STA, the post-PnR pipelining pass,
//!   the bitstream encoder and the fabric simulator all consume.

pub mod netlist;
pub mod place;
pub mod route;
pub mod design;

pub use design::RoutedDesign;
pub use netlist::{build_nets, Net, NetKind};
pub use place::{place, PlaceParams, Placement};
pub use route::{route, RouteError, RouteParams};

use crate::arch::canal::InterconnectGraph;
use crate::arch::delay::DelayLib;
use crate::arch::params::ArchParams;
use crate::dfg::ir::Dfg;

/// Convenience: run placement and routing with the given knobs and return
/// the routed design. The interconnect graph must already carry delays
/// (`annotate_delays`).
pub fn place_and_route(
    dfg: &Dfg,
    arch: &ArchParams,
    graph: &InterconnectGraph,
    lib: &DelayLib,
    pp: &PlaceParams,
    rp: &RouteParams,
) -> Result<RoutedDesign, RouteError> {
    let nets = build_nets(dfg, arch);
    let placement = place(dfg, &nets, arch, pp);
    crate::obs::trace::mark("place");
    let routes = route(dfg, &nets, &placement, arch, graph, rp)?;
    crate::obs::trace::mark("route");
    Ok(RoutedDesign::new(dfg.clone(), nets, placement, routes, arch.clone(), lib.clone()))
}
