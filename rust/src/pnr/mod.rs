//! Place and route.
//!
//! * [`netlist`] — net extraction from the DFG, including the sparse
//!   valid/ready companion nets (§VII: "If a piece of data is routed from
//!   Tile A to Tile B, a valid signal will be routed in the exact same way
//!   ... A ready signal will be routed in the same way but in the opposite
//!   direction") and the flush broadcast net (§VI), which is omitted when
//!   the architecture hardens it.
//! * [`place`] — simulated-annealing detailed placement with the paper's
//!   Eq. 1 cost: `Cost_net = (HPWL_net + gamma * Area_passthrough)^alpha`,
//!   where `alpha` is Cascade's criticality exponent (§V-C).
//! * [`route`] — PathFinder-style negotiated-congestion routing over the
//!   Canal interconnect graph, producing per-net route trees.
//! * [`design`] — the routed design: placement + route trees + enabled
//!   pipelining registers; the object STA, the post-PnR pipelining pass,
//!   the bitstream encoder and the fabric simulator all consume.
//!
//! The three hot kernels (annealing placement, PathFinder routing, and
//! [`crate::timing::sta`]) each have an incremental evaluation mode gated
//! by [`IncrementalCfg`] (default on; `cascade --no-incremental` turns it
//! off process-wide). Incremental mode is pure memoization: both modes run
//! the same algorithm over the same decision sequence and produce
//! **bit-identical** placements, routes, timing reports, bitstreams and
//! cache keys — the contract is written down in `docs/performance.md` and
//! asserted end to end in `tests/explore.rs`.

pub mod netlist;
pub mod place;
pub mod route;
pub mod design;

pub use design::RoutedDesign;
pub use netlist::{build_nets, Net, NetKind};
pub use place::{place, PlaceParams, Placement};
pub use route::{route, RouteError, RouteParams};

use std::sync::atomic::{AtomicU8, Ordering};

/// Which hot kernels run in incremental mode. The default is all-on; the
/// CLI's global `--no-incremental` flag installs the all-off configuration
/// as an escape hatch (and as the reference side of the byte-identity
/// contract: outputs never depend on these switches).
///
/// The configuration is deliberately **not** part of
/// [`crate::pipeline::PipelineConfig`] or the explore spec: it cannot
/// influence any compiled
/// output, so it must not reach `config_signature` / cache keys / report
/// JSON. It lives in one process-wide atomic instead, consulted by the
/// compile driver when it builds [`PlaceParams`] / [`RouteParams`] and by
/// the post-PnR pass when it decides whether to keep a
/// [`crate::timing::sta::StaEngine`] across iterations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IncrementalCfg {
    /// Delta-cost placement moves (incremental net bounding boxes).
    pub place: bool,
    /// Selective rip-up bookkeeping in the router.
    pub route: bool,
    /// Dirty-set STA re-propagation across post-PnR iterations.
    pub sta: bool,
}

/// Bitmask encoding of [`IncrementalCfg`] (bit 0 = place, 1 = route,
/// 2 = sta). Default: everything on.
static INCREMENTAL: AtomicU8 = AtomicU8::new(0b111);

impl IncrementalCfg {
    /// All kernels incremental (the default).
    pub fn on() -> IncrementalCfg {
        IncrementalCfg { place: true, route: true, sta: true }
    }

    /// Full recompute everywhere (`--no-incremental`).
    pub fn off() -> IncrementalCfg {
        IncrementalCfg { place: false, route: false, sta: false }
    }

    /// The process-wide configuration currently installed.
    pub fn current() -> IncrementalCfg {
        let bits = INCREMENTAL.load(Ordering::Relaxed);
        IncrementalCfg { place: bits & 1 != 0, route: bits & 2 != 0, sta: bits & 4 != 0 }
    }

    /// Install this configuration process-wide. Affects only *how* later
    /// compiles are computed, never *what* they compute.
    pub fn install(&self) {
        let bits =
            (self.place as u8) | ((self.route as u8) << 1) | ((self.sta as u8) << 2);
        INCREMENTAL.store(bits, Ordering::Relaxed);
    }
}

use crate::arch::canal::InterconnectGraph;
use crate::arch::delay::DelayLib;
use crate::arch::params::ArchParams;
use crate::dfg::ir::Dfg;

/// Convenience: run placement and routing with the given knobs and return
/// the routed design. The interconnect graph must already carry delays
/// (`annotate_delays`).
pub fn place_and_route(
    dfg: &Dfg,
    arch: &ArchParams,
    graph: &InterconnectGraph,
    lib: &DelayLib,
    pp: &PlaceParams,
    rp: &RouteParams,
) -> Result<RoutedDesign, RouteError> {
    let nets = build_nets(dfg, arch);
    let placement = place(dfg, &nets, arch, pp);
    crate::obs::trace::mark("place");
    let routes = route(dfg, &nets, &placement, arch, graph, rp)?;
    crate::obs::trace::mark("route");
    Ok(RoutedDesign::new(dfg.clone(), nets, placement, routes, arch.clone(), lib.clone()))
}
