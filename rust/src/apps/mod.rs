//! Benchmark applications from the paper's evaluation.
//!
//! Dense (§VIII-B, Table I): Gaussian, Unsharp, Camera, Harris, ResNet
//! (one conv5_x layer of ResNet-18). Sparse (§VIII-D, Table II): vector
//! elementwise add, matrix elementwise mul, tensor MTTKRP, tensor TTV.
//!
//! Each constructor is parameterized over frame geometry and unrolling so
//! the same application can be built paper-scale (for the schedule/runtime
//! numbers) and test-scale (for cycle-accurate functional simulation).

pub mod dense;
pub mod sparse;

use crate::dfg::ir::Dfg;
use crate::schedule::WorkloadShape;

/// Application domain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppKind {
    /// Statically scheduled (image processing / ML).
    Dense,
    /// Ready-valid / data-dependent (sparse tensor algebra).
    Sparse,
}

/// A benchmark application instance.
pub struct App {
    pub name: &'static str,
    pub kind: AppKind,
    pub dfg: Dfg,
    pub shape: WorkloadShape,
    /// Name of the AOT golden-model artifact (`artifacts/<name>.hlo.txt`)
    /// that computes the same function (dense apps only).
    pub golden: Option<&'static str>,
}

/// The paper's five dense applications at paper-scale frame sizes
/// (Table I: 6400x4800 Gaussian, 1536x2560 Unsharp, 2560x1920 Camera,
/// 1530x2554 Harris, ResNet conv5_x).
pub fn paper_dense_suite() -> Vec<App> {
    vec![
        dense::gaussian(6400, 4800, 16),
        dense::unsharp(1536, 2560, 4),
        dense::camera(2560, 1920, 4),
        dense::harris(1530, 2554, 4),
        dense::resnet_conv5x(),
    ]
}

/// Small-frame versions of the dense suite for cycle-accurate functional
/// simulation in tests and the quickstart example.
pub fn small_dense_suite() -> Vec<App> {
    vec![
        dense::gaussian(64, 64, 2),
        dense::unsharp(64, 64, 1),
        dense::camera(64, 64, 1),
        dense::harris(64, 64, 1),
        dense::resnet_small(),
    ]
}

/// Every benchmark name the CLI and the `explore` grid accept.
pub const APP_NAMES: [&str; 9] = [
    "gaussian", "unsharp", "camera", "harris", "resnet",
    "vec_elemadd", "mat_elemmul", "mttkrp", "ttv",
];

/// Build a benchmark by name at paper-scale dimensions (Table I / II).
/// Sparse workloads always use paper dimensions — their input bundles
/// (`sparse::data_for`) are generated at those shapes.
pub fn by_name(name: &str) -> Option<App> {
    Some(match name {
        "gaussian" => dense::gaussian(6400, 4800, 16),
        "unsharp" => dense::unsharp(1536, 2560, 4),
        "camera" => dense::camera(2560, 1920, 4),
        "harris" => dense::harris(1530, 2554, 4),
        "resnet" => dense::resnet_conv5x(),
        "vec_elemadd" => sparse::vec_elemadd(4096, 0.25),
        "mat_elemmul" => sparse::mat_elemmul(128, 128, 0.1),
        "mttkrp" => sparse::tensor_mttkrp(32, 32, 32, 8, 0.05),
        "ttv" => sparse::tensor_ttv(48, 48, 48, 0.05),
        _ => return None,
    })
}

/// Build a benchmark by name at test-scale dimensions (small frames for
/// cycle-accurate simulation and fast unit tests). Sparse workloads are
/// paper-scale for the reason given on [`by_name`].
pub fn by_name_tiny(name: &str) -> Option<App> {
    Some(match name {
        "gaussian" => dense::gaussian(64, 64, 2),
        "unsharp" => dense::unsharp(64, 64, 1),
        "camera" => dense::camera(64, 64, 1),
        "harris" => dense::harris(64, 64, 1),
        "resnet" => dense::resnet_small(),
        _ => return by_name(name),
    })
}

/// Whether a benchmark name denotes a sparse (ready-valid) workload.
pub fn is_sparse_name(name: &str) -> bool {
    matches!(name, "vec_elemadd" | "mat_elemmul" | "mttkrp" | "ttv")
}

/// The paper's four sparse applications (Table II).
pub fn paper_sparse_suite() -> Vec<App> {
    vec![
        sparse::vec_elemadd(4096, 0.25),
        sparse::mat_elemmul(128, 128, 0.1),
        sparse::tensor_mttkrp(32, 32, 32, 8, 0.05),
        sparse::tensor_ttv(48, 48, 48, 0.05),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_suite_builds_and_validates() {
        for app in paper_dense_suite() {
            let problems = app.dfg.validate();
            assert!(problems.is_empty(), "{}: {:?}", app.name, problems);
            assert_eq!(app.kind, AppKind::Dense);
            assert!(app.golden.is_some());
        }
    }

    #[test]
    fn paper_suite_fits_paper_array() {
        let arch = crate::arch::params::ArchParams::paper();
        let (pe_cap, mem_cap) = arch.core_tile_counts();
        for app in paper_dense_suite() {
            let (pe, mem, io) = app.dfg.tile_demand();
            assert!(pe <= pe_cap, "{}: {pe} PEs > {pe_cap}", app.name);
            assert!(mem <= mem_cap, "{}: {mem} MEMs > {mem_cap}", app.name);
            // IO tiles host one input and one output node each.
            assert!(io <= 2 * arch.cols, "{}: {io} IO nodes", app.name);
        }
    }

    #[test]
    fn small_suite_builds() {
        for app in small_dense_suite() {
            assert!(app.dfg.validate().is_empty(), "{}", app.name);
        }
    }
}
