//! The four sparse benchmark applications (paper Table II), built on the
//! ready-valid streaming substrate of `sparse`.
//!
//! Sparse tensor kernels have data-dependent memory accesses, so they
//! cannot be statically scheduled; every inter-tile connection carries a
//! data/valid/ready triple (§VII). The dataflow style follows the
//! tensor-algebra compilers the paper cites [18]: per-mode coordinate
//! scanners over compressed fibers, coordinate intersect/union combinators,
//! elementwise ALUs, and fiber reductions.
//!
//! Tensors are synthetic (deterministic seeds) — the paper's sparse inputs
//! come from [18]'s suite, which we stand in for with uniform-random
//! sparsity at the paper's workload scales (see DESIGN.md §2).

use crate::dfg::ir::{AluOp, Dfg, NodeId, Op, SparseOp};
use crate::schedule::WorkloadShape;
use crate::util::rng::Rng;

use super::{App, AppKind};

/// A sparse tensor in sorted COO form (coordinates lexicographic).
#[derive(Debug, Clone, Default)]
pub struct SparseTensor {
    /// Dimensionality (1-3).
    pub ndim: usize,
    /// Shape per mode.
    pub shape: Vec<u32>,
    /// Sorted coordinates, one Vec per nonzero.
    pub coords: Vec<Vec<u32>>,
    pub values: Vec<i64>,
}

impl SparseTensor {
    /// Generate a uniform-random sparse tensor with ~`density` nonzeros.
    pub fn random(shape: &[u32], density: f64, seed: u64) -> SparseTensor {
        let mut rng = Rng::new(seed);
        let mut coords = Vec::new();
        let mut values = Vec::new();
        let total: u64 = shape.iter().map(|&s| s as u64).product();
        // Iterate the dense space only for small tensors; sample for large.
        if total <= 1 << 22 {
            let mut idx = vec![0u32; shape.len()];
            loop {
                if rng.gen_bool(density) {
                    coords.push(idx.clone());
                    values.push(rng.gen_range_i64(-8, 8).max(1));
                }
                // Increment mixed-radix counter.
                let mut d = shape.len();
                loop {
                    if d == 0 {
                        return SparseTensor {
                            ndim: shape.len(),
                            shape: shape.to_vec(),
                            coords,
                            values,
                        };
                    }
                    d -= 1;
                    idx[d] += 1;
                    if idx[d] < shape[d] {
                        break;
                    }
                    idx[d] = 0;
                }
            }
        } else {
            let n = (total as f64 * density) as usize;
            let mut set = std::collections::BTreeSet::new();
            while set.len() < n {
                let c: Vec<u32> = shape.iter().map(|&s| rng.gen_range(s as usize) as u32).collect();
                set.insert(c);
            }
            for c in set {
                coords.push(c);
                values.push(rng.gen_range_i64(-8, 8).max(1));
            }
            SparseTensor { ndim: shape.len(), shape: shape.to_vec(), coords, values }
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Dense lookup (slow; for golden checks).
    pub fn get(&self, coord: &[u32]) -> i64 {
        self.coords
            .iter()
            .position(|c| c == coord)
            .map(|i| self.values[i])
            .unwrap_or(0)
    }
}

/// Input data for a sparse app: tensors indexed by the `tensor` field of
/// `SparseOp::CrdScan`/`ValRead`.
#[derive(Debug, Clone, Default)]
pub struct SparseData {
    pub tensors: Vec<SparseTensor>,
}

/// A sparse application bundle: the DFG plus its input data.
pub struct SparseAppData {
    pub app: App,
    pub data: SparseData,
}

fn sp(g: &mut Dfg, op: SparseOp, name: impl Into<String>) -> NodeId {
    g.add_node(Op::Sparse(op), name)
}

/// Connect a sparse data edge (the valid/ready companions are implied and
/// expanded during routing).
fn sconnect(g: &mut Dfg, src: NodeId, dst: NodeId, port: u8) {
    g.connect(src, dst, port);
}

/// Vector elementwise add: `a(i) = b(i) + c(i)` over sparse b, c.
pub fn vec_elemadd(n: u32, density: f64) -> App {
    let mut g = Dfg::new();
    let sb = sp(&mut g, SparseOp::CrdScan { tensor: 0, mode: 0 }, "scan_b");
    let sc = sp(&mut g, SparseOp::CrdScan { tensor: 1, mode: 0 }, "scan_c");
    let un = sp(&mut g, SparseOp::Union, "union_i");
    sconnect(&mut g, sb, un, 0);
    sconnect(&mut g, sc, un, 1);
    let vb = sp(&mut g, SparseOp::ValRead { tensor: 0 }, "val_b");
    let vc = sp(&mut g, SparseOp::ValRead { tensor: 1 }, "val_c");
    sconnect(&mut g, un, vb, 0);
    let unp = sp(&mut g, SparseOp::Repeat, "un_p");
    sconnect(&mut g, un, unp, 0);
    sconnect(&mut g, unp, vc, 0);
    let add = sp(&mut g, SparseOp::SpAlu(AluOp::Add), "add");
    sconnect(&mut g, vb, add, 0);
    sconnect(&mut g, vc, add, 1);
    let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "out");
    sconnect(&mut g, add, o, 0);
    let nnz = (n as f64 * density) as u64;
    App {
        name: "vec_elemadd",
        kind: AppKind::Sparse,
        dfg: g,
        // Work ~ |b| + |c| merged coordinates.
        shape: WorkloadShape { frame_w: 2 * nnz, frame_h: 1, unroll: 1, time_mult: 1 },
        golden: Some("vec_elemadd"),
    }
}

/// Matrix elementwise multiply: `A(i,j) = B(i,j) * C(i,j)` (intersection
/// on both modes).
pub fn mat_elemmul(rows: u32, cols: u32, density: f64) -> App {
    let mut g = Dfg::new();
    let sb_i = sp(&mut g, SparseOp::CrdScan { tensor: 0, mode: 0 }, "scan_b_i");
    let sc_i = sp(&mut g, SparseOp::CrdScan { tensor: 1, mode: 0 }, "scan_c_i");
    let int_i = sp(&mut g, SparseOp::Intersect, "int_i");
    sconnect(&mut g, sb_i, int_i, 0);
    sconnect(&mut g, sc_i, int_i, 1);
    let sb_j = sp(&mut g, SparseOp::CrdScan { tensor: 0, mode: 1 }, "scan_b_j");
    let sc_j = sp(&mut g, SparseOp::CrdScan { tensor: 1, mode: 1 }, "scan_c_j");
    sconnect(&mut g, int_i, sb_j, 0);
    let int_ip = sp(&mut g, SparseOp::Repeat, "int_ip");
    sconnect(&mut g, int_i, int_ip, 0);
    sconnect(&mut g, int_ip, sc_j, 0);
    let int_j = sp(&mut g, SparseOp::Intersect, "int_j");
    sconnect(&mut g, sb_j, int_j, 0);
    sconnect(&mut g, sc_j, int_j, 1);
    let vb = sp(&mut g, SparseOp::ValRead { tensor: 0 }, "val_b");
    let vc = sp(&mut g, SparseOp::ValRead { tensor: 1 }, "val_c");
    sconnect(&mut g, int_j, vb, 0);
    let int_jp = sp(&mut g, SparseOp::Repeat, "int_jp");
    sconnect(&mut g, int_j, int_jp, 0);
    sconnect(&mut g, int_jp, vc, 0);
    let mul = sp(&mut g, SparseOp::SpAlu(AluOp::Mul), "mul");
    sconnect(&mut g, vb, mul, 0);
    sconnect(&mut g, vc, mul, 1);
    let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "out");
    sconnect(&mut g, mul, o, 0);
    let nnz = (rows as f64 * cols as f64 * density) as u64;
    App {
        name: "mat_elemmul",
        kind: AppKind::Sparse,
        dfg: g,
        shape: WorkloadShape { frame_w: 2 * nnz, frame_h: 1, unroll: 1, time_mult: 1 },
        golden: Some("mat_elemmul"),
    }
}

/// Tensor MTTKRP: `A(i,j) = sum_{k,l} B(i,k,l) * C(k,j) * D(l,j)`, the
/// matricized tensor times Khatri-Rao product.
///
/// Dataflow (SAM-style): the B fiber tree is scanned i -> k -> l; the C
/// factor read is indexed by `k`, held and repeated once per `l` (a
/// two-input Repeat whose second input is the reference stream, exactly
/// [18]'s repeat operator); values expand across the `j` lane dimension at
/// the dense factor reads; the reduction resets at the end of each `k`
/// fiber (End level 1), producing one row A(i, 0..j) per `i`.
pub fn tensor_mttkrp(i: u32, k: u32, l: u32, j: u32, density: f64) -> App {
    let mut g = Dfg::new();
    let s_i = sp(&mut g, SparseOp::CrdScan { tensor: 0, mode: 0 }, "scan_b_i");
    let s_k = sp(&mut g, SparseOp::CrdScan { tensor: 0, mode: 1 }, "scan_b_k");
    let s_l = sp(&mut g, SparseOp::CrdScan { tensor: 0, mode: 2 }, "scan_b_l");
    sconnect(&mut g, s_i, s_k, 0);
    sconnect(&mut g, s_k, s_l, 0);
    let vb = sp(&mut g, SparseOp::ValRead { tensor: 0 }, "val_b");
    sconnect(&mut g, s_l, vb, 0);
    // rep_j: expand each B value across the j lanes.
    let rep_j = sp(&mut g, SparseOp::Repeat, "rep_j");
    sconnect(&mut g, vb, rep_j, 0);
    // Pass-through taps of the l-coordinate stream (fanout legalization).
    let s_lp = sp(&mut g, SparseOp::Repeat, "l_pass");
    sconnect(&mut g, s_l, s_lp, 0);
    let s_lp2 = sp(&mut g, SparseOp::Repeat, "l_pass2");
    sconnect(&mut g, s_lp, s_lp2, 0);
    // k_rep: hold each k coordinate, repeat once per l element (2-input
    // Repeat; port 1 is the reference stream).
    let k_rep = sp(&mut g, SparseOp::Repeat, "k_rep");
    sconnect(&mut g, s_k, k_rep, 0);
    sconnect(&mut g, s_lp, k_rep, 1);
    // Dense factor reads: C indexed by k, D indexed by l; each expands
    // into the j lanes.
    let vc = sp(&mut g, SparseOp::ValRead { tensor: 1 }, "val_c");
    sconnect(&mut g, k_rep, vc, 0);
    let vd = sp(&mut g, SparseOp::ValRead { tensor: 2 }, "val_d");
    sconnect(&mut g, s_lp2, vd, 0);
    let m1 = sp(&mut g, SparseOp::SpAlu(AluOp::Mul), "mul1");
    sconnect(&mut g, rep_j, m1, 0);
    sconnect(&mut g, vc, m1, 1);
    let m2 = sp(&mut g, SparseOp::SpAlu(AluOp::Mul), "mul2");
    sconnect(&mut g, m1, m2, 0);
    sconnect(&mut g, vd, m2, 1);
    let red = sp(&mut g, SparseOp::Reduce, "red_kl");
    sconnect(&mut g, m2, red, 0);
    let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "out");
    sconnect(&mut g, red, o, 0);
    let nnz = (i as f64 * k as f64 * l as f64 * density) as u64;
    App {
        name: "mttkrp",
        kind: AppKind::Sparse,
        dfg: g,
        shape: WorkloadShape { frame_w: nnz * j as u64, frame_h: 1, unroll: 1, time_mult: 1 },
        golden: Some("mttkrp"),
    }
}

/// Tensor-times-vector: `A(i,j) = sum_k B(i,j,k) * c(k)`.
pub fn tensor_ttv(i: u32, j: u32, k: u32, density: f64) -> App {
    let mut g = Dfg::new();
    let s_i = sp(&mut g, SparseOp::CrdScan { tensor: 0, mode: 0 }, "scan_b_i");
    let s_j = sp(&mut g, SparseOp::CrdScan { tensor: 0, mode: 1 }, "scan_b_j");
    let s_k = sp(&mut g, SparseOp::CrdScan { tensor: 0, mode: 2 }, "scan_b_k");
    sconnect(&mut g, s_i, s_j, 0);
    sconnect(&mut g, s_j, s_k, 0);
    let vb = sp(&mut g, SparseOp::ValRead { tensor: 0 }, "val_b");
    sconnect(&mut g, s_k, vb, 0);
    let vc = sp(&mut g, SparseOp::ValRead { tensor: 1 }, "val_c");
    let s_kp = sp(&mut g, SparseOp::Repeat, "k_rep");
    sconnect(&mut g, s_k, s_kp, 0);
    sconnect(&mut g, s_kp, vc, 0);
    let mul = sp(&mut g, SparseOp::SpAlu(AluOp::Mul), "mul");
    sconnect(&mut g, vb, mul, 0);
    sconnect(&mut g, vc, mul, 1);
    let red = sp(&mut g, SparseOp::Reduce, "red_k");
    sconnect(&mut g, mul, red, 0);
    let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "out");
    sconnect(&mut g, red, o, 0);
    let nnz = (i as f64 * j as f64 * k as f64 * density) as u64;
    App {
        name: "ttv",
        kind: AppKind::Sparse,
        dfg: g,
        shape: WorkloadShape { frame_w: nnz, frame_h: 1, unroll: 1, time_mult: 1 },
        golden: Some("ttv"),
    }
}

/// Generate the input data bundle for a sparse app by name.
pub fn data_for(name: &str, seed: u64) -> SparseData {
    match name {
        "vec_elemadd" => SparseData {
            tensors: vec![
                SparseTensor::random(&[4096], 0.25, seed),
                SparseTensor::random(&[4096], 0.25, seed + 1),
            ],
        },
        "mat_elemmul" => SparseData {
            tensors: vec![
                SparseTensor::random(&[128, 128], 0.1, seed),
                SparseTensor::random(&[128, 128], 0.1, seed + 1),
            ],
        },
        "mttkrp" => SparseData {
            tensors: vec![
                SparseTensor::random(&[32, 32, 32], 0.05, seed),
                SparseTensor::random(&[32, 8], 1.0, seed + 1), // dense factor C
                SparseTensor::random(&[32, 8], 1.0, seed + 2), // dense factor D
            ],
        },
        "ttv" => SparseData {
            tensors: vec![
                SparseTensor::random(&[48, 48, 48], 0.05, seed),
                SparseTensor::random(&[48], 1.0, seed + 1), // dense vector c
            ],
        },
        _ => panic!("unknown sparse app {name}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensors_deterministic() {
        let a = SparseTensor::random(&[64, 64], 0.1, 7);
        let b = SparseTensor::random(&[64, 64], 0.1, 7);
        assert_eq!(a.coords, b.coords);
        assert_eq!(a.values, b.values);
        let frac = a.nnz() as f64 / (64.0 * 64.0);
        assert!((frac - 0.1).abs() < 0.03, "density {frac}");
    }

    #[test]
    fn tensors_sorted_lexicographic() {
        let t = SparseTensor::random(&[16, 16, 16], 0.05, 3);
        for w in t.coords.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn all_sparse_apps_validate() {
        for app in crate::apps::paper_sparse_suite() {
            assert!(app.dfg.validate().is_empty(), "{}: {:?}", app.name, app.dfg.validate());
            assert_eq!(app.kind, AppKind::Sparse);
            // Every non-IO node is sparse.
            for n in &app.dfg.nodes {
                let is_io = matches!(n.op, Op::Input { .. } | Op::Output { .. } | Op::FlushSrc);
                assert!(is_io || n.is_sparse(), "{}: {:?}", app.name, n.op);
            }
        }
    }

    #[test]
    fn sparse_apps_fit_array() {
        let arch = crate::arch::params::ArchParams::paper();
        let (pe_cap, mem_cap) = arch.core_tile_counts();
        for app in crate::apps::paper_sparse_suite() {
            let (pe, mem, _) = app.dfg.tile_demand();
            assert!(pe <= pe_cap && mem <= mem_cap, "{}", app.name);
        }
    }

    #[test]
    fn data_bundles_have_expected_arity() {
        assert_eq!(data_for("vec_elemadd", 1).tensors.len(), 2);
        assert_eq!(data_for("mat_elemmul", 1).tensors.len(), 2);
        assert_eq!(data_for("mttkrp", 1).tensors.len(), 3);
        assert_eq!(data_for("ttv", 1).tensors.len(), 2);
    }

    #[test]
    fn dense_factors_are_dense() {
        let d = data_for("ttv", 5);
        assert_eq!(d.tensors[1].nnz(), 48);
    }
}
