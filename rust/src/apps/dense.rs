//! The five dense benchmark applications (paper Table I).
//!
//! Each is built per-lane: with unrolling `U`, lane `u` processes the
//! `u`-th of `U` interleaved pixel sub-streams, producing `U` output pixels
//! per cycle in steady state (§V-E: "Image processing and machine learning
//! applications are typically unrolled on hardware accelerators ...
//! producing more than one output pixel per cycle"). Every application also
//! carries the global flush broadcast (§VI): a 1-bit net from an IO tile to
//! every stateful tile (line buffers, ROMs, accumulators).

use crate::arch::canal::Layer;
use crate::dfg::build::{stencil, tap_line, weighted_sum};
use crate::dfg::ir::{AluOp, Dfg, NodeId, Op};
use crate::schedule::WorkloadShape;

use super::{App, AppKind};

/// Attach the flush broadcast: a 1-bit edge from a FlushSrc IO node to
/// every stateful node (MEM line buffers, ROMs, accumulators, and PEs with
/// register files). This is the net §VI hardens.
pub fn attach_flush(g: &mut Dfg) -> NodeId {
    let flush = g.add_node(Op::FlushSrc, "flush");
    let targets: Vec<NodeId> = g
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| {
            matches!(n.op, Op::Delay { .. } | Op::Rom { .. } | Op::Accum { .. })
        })
        .map(|(i, _)| i as NodeId)
        .collect();
    for t in targets {
        g.add_edge(flush, t, 1, Layer::B1);
    }
    flush
}

/// 3x3 Gaussian blur: `out = [1 2 1; 2 4 2; 1 2 1] * in >> 4`.
pub fn gaussian(w: u64, h: u64, unroll: u64) -> App {
    let mut g = Dfg::new();
    let lane_w = (w / unroll) as u32;
    let weights = vec![vec![1, 2, 1], vec![2, 4, 2], vec![1, 2, 1]];
    for u in 0..unroll {
        let i = g.add_node(Op::Input { lane: u as u16 }, format!("in{u}"));
        let s = stencil(&mut g, i, lane_w, &weights, &format!("g{u}"));
        let norm = g.add_node(Op::Alu { op: AluOp::Shr, const_b: Some(4) }, format!("norm{u}"));
        g.connect(s, norm, 0);
        let o = g.add_node(Op::Output { lane: u as u16, decimate: 1 }, format!("out{u}"));
        g.connect(norm, o, 0);
    }
    attach_flush(&mut g);
    App {
        name: "gaussian",
        kind: AppKind::Dense,
        dfg: g,
        shape: WorkloadShape::stencil(w, h, unroll),
        golden: Some("gaussian"),
    }
}

/// Unsharp masking: `out = in + ((in - blur(in)) * k >> s)`, blur = 3x3
/// Gaussian. The aligned original requires delaying the input by the
/// stencil window.
pub fn unsharp(w: u64, h: u64, unroll: u64) -> App {
    let mut g = Dfg::new();
    let lane_w = (w / unroll) as u32;
    let weights = vec![vec![1, 2, 1], vec![2, 4, 2], vec![1, 2, 1]];
    let window = crate::dfg::build::stencil_window_delay(lane_w, 3);
    for u in 0..unroll {
        let i = g.add_node(Op::Input { lane: u as u16 }, format!("in{u}"));
        let blur = stencil(&mut g, i, lane_w, &weights, &format!("b{u}"));
        let bn = g.add_node(Op::Alu { op: AluOp::Shr, const_b: Some(4) }, format!("bn{u}"));
        g.connect(blur, bn, 0);
        // Align the original with the blur output (window-centre tap).
        let center = g
            .add_node(Op::Delay { cycles: window / 2 + 1, pipelined: false }, format!("ctr{u}"));
        g.connect(i, center, 0);
        let pad = g.add_node(
            Op::Delay { cycles: window - (window / 2 + 1), pipelined: false },
            format!("pad{u}"),
        );
        g.connect(center, pad, 0);
        let diff = g.add_node(Op::Alu { op: AluOp::Sub, const_b: None }, format!("diff{u}"));
        g.connect(pad, diff, 0);
        g.connect(bn, diff, 1);
        let amp = g.add_node(Op::Alu { op: AluOp::Mul, const_b: Some(3) }, format!("amp{u}"));
        g.connect(diff, amp, 0);
        let sc = g.add_node(Op::Alu { op: AluOp::Shr, const_b: Some(2) }, format!("sc{u}"));
        g.connect(amp, sc, 0);
        let sum = g.add_node(Op::Alu { op: AluOp::Add, const_b: None }, format!("sum{u}"));
        // Second aligned tap of the original for the final add.
        let orig2 = g.add_node(Op::Alu { op: AluOp::Pass, const_b: None }, format!("orig2_{u}"));
        g.connect(pad, orig2, 0);
        g.connect(orig2, sum, 0);
        g.connect(sc, sum, 1);
        let o = g.add_node(Op::Output { lane: u as u16, decimate: 1 }, format!("out{u}"));
        g.connect(sum, o, 0);
    }
    attach_flush(&mut g);
    App {
        name: "unsharp",
        kind: AppKind::Dense,
        dfg: g,
        shape: WorkloadShape::stencil(w, h, unroll),
        golden: Some("unsharp"),
    }
}

/// Camera pipeline (black-level correction, demosaic-lite interpolation,
/// color mix, piecewise gamma via compare+mux — exercises the 1-bit layer
/// with real control data).
pub fn camera(w: u64, h: u64, unroll: u64) -> App {
    let mut g = Dfg::new();
    let lane_w = (w / unroll) as u32;
    for u in 0..unroll {
        let i = g.add_node(Op::Input { lane: u as u16 }, format!("in{u}"));
        // 1. Black level: max(in - 16, 0).
        let bl = g.add_node(Op::Alu { op: AluOp::Sub, const_b: Some(16) }, format!("bl{u}"));
        g.connect(i, bl, 0);
        let clamp = g.add_node(Op::Alu { op: AluOp::Max, const_b: Some(0) }, format!("cl{u}"));
        g.connect(bl, clamp, 0);
        // 2. Demosaic-lite: cross-shaped interpolation stencil.
        let dem_w = vec![vec![0, 1, 0], vec![1, 4, 1], vec![0, 1, 0]];
        let dem = stencil(&mut g, clamp, lane_w, &dem_w, &format!("dem{u}"));
        let demn = g.add_node(Op::Alu { op: AluOp::Shr, const_b: Some(3) }, format!("demn{u}"));
        g.connect(dem, demn, 0);
        // 3. Color mix: combine three chroma-offset taps.
        let line = tap_line(&mut g, demn, &[0, 1, 2], &format!("cc{u}"));
        let mixed = weighted_sum(&mut g, &line.taps, &[5, 2, 1], &format!("mix{u}"));
        let mixn = g.add_node(Op::Alu { op: AluOp::Shr, const_b: Some(3) }, format!("mixn{u}"));
        g.connect(mixed, mixn, 0);
        // 4. Gamma: out = p < 64 ? p*2 : p/2 + 96 (piecewise linear),
        //    selector on the 1-bit layer.
        let lo = g.add_node(Op::Alu { op: AluOp::Shl, const_b: Some(1) }, format!("glo{u}"));
        g.connect(mixn, lo, 0);
        let hi0 = g.add_node(Op::Alu { op: AluOp::Shr, const_b: Some(1) }, format!("ghi0{u}"));
        g.connect(mixn, hi0, 0);
        let hi = g.add_node(Op::Alu { op: AluOp::Add, const_b: Some(96) }, format!("ghi{u}"));
        g.connect(hi0, hi, 0);
        let cmp = g.add_node(Op::Alu { op: AluOp::Gte, const_b: Some(64) }, format!("gc{u}"));
        g.connect(mixn, cmp, 0);
        let mux = g.add_node(Op::Alu { op: AluOp::Mux, const_b: None }, format!("gmux{u}"));
        g.connect(lo, mux, 0);
        g.connect(hi, mux, 1);
        g.add_edge(cmp, mux, 0, Layer::B1);
        let o = g.add_node(Op::Output { lane: u as u16, decimate: 1 }, format!("out{u}"));
        g.connect(mux, o, 0);
    }
    attach_flush(&mut g);
    App {
        name: "camera",
        kind: AppKind::Dense,
        dfg: g,
        shape: WorkloadShape::stencil(w, h, unroll),
        golden: Some("camera"),
    }
}

/// Harris corner detection: Sobel gradients, structure-tensor window sums,
/// and the corner response `det(M) - k*trace(M)^2`. The deepest dense
/// pipeline (lowest unpipelined frequency in Table I).
pub fn harris(w: u64, h: u64, unroll: u64) -> App {
    let mut g = Dfg::new();
    let lane_w = (w / unroll) as u32;
    for u in 0..unroll {
        let i = g.add_node(Op::Input { lane: u as u16 }, format!("in{u}"));
        // Shared 3x3 tap line for both Sobel kernels.
        let mut delays = Vec::new();
        for r in 0..3u32 {
            for c in 0..3u32 {
                delays.push(r * lane_w + c);
            }
        }
        let line = tap_line(&mut g, i, &delays, &format!("wd{u}"));
        let tap = |r: usize, c: usize| line.taps[r * 3 + c];
        // Sobel X: [-1 0 1; -2 0 2; -1 0 1].
        let sx_taps = vec![tap(0, 0), tap(0, 2), tap(1, 0), tap(1, 2), tap(2, 0), tap(2, 2)];
        let sx = weighted_sum(&mut g, &sx_taps, &[-1, 1, -2, 2, -1, 1], &format!("sx{u}"));
        // Sobel Y: [-1 -2 -1; 0 0 0; 1 2 1].
        let sy_taps = vec![tap(0, 0), tap(0, 1), tap(0, 2), tap(2, 0), tap(2, 1), tap(2, 2)];
        let sy = weighted_sum(&mut g, &sy_taps, &[-1, -2, -1, 1, 2, 1], &format!("sy{u}"));
        // Products.
        let ixx = g.add_node(Op::Alu { op: AluOp::Mul, const_b: None }, format!("ixx{u}"));
        g.connect(sx, ixx, 0);
        let sx2 = g.add_node(Op::Alu { op: AluOp::Pass, const_b: None }, format!("sx2_{u}"));
        g.connect(sx, sx2, 0);
        g.connect(sx2, ixx, 1);
        let iyy = g.add_node(Op::Alu { op: AluOp::Mul, const_b: None }, format!("iyy{u}"));
        g.connect(sy, iyy, 0);
        let sy2 = g.add_node(Op::Alu { op: AluOp::Pass, const_b: None }, format!("sy2_{u}"));
        g.connect(sy, sy2, 0);
        g.connect(sy2, iyy, 1);
        let ixy = g.add_node(Op::Alu { op: AluOp::Mul, const_b: None }, format!("ixy{u}"));
        g.connect(sx, ixy, 0);
        g.connect(sy, ixy, 1);
        // 3x3 window sums of each product.
        let ones = vec![vec![1, 1, 1], vec![1, 1, 1], vec![1, 1, 1]];
        let sxx = stencil(&mut g, ixx, lane_w, &ones, &format!("sxx{u}"));
        let syy = stencil(&mut g, iyy, lane_w, &ones, &format!("syy{u}"));
        let sxy = stencil(&mut g, ixy, lane_w, &ones, &format!("sxy{u}"));
        // Response: det - k*tr^2 with k ~ 1/16.
        let det1 = g.add_node(Op::Alu { op: AluOp::Mul, const_b: None }, format!("det1{u}"));
        g.connect(sxx, det1, 0);
        g.connect(syy, det1, 1);
        let det2 = g.add_node(Op::Alu { op: AluOp::Mul, const_b: None }, format!("det2{u}"));
        g.connect(sxy, det2, 0);
        let sxy2 = g.add_node(Op::Alu { op: AluOp::Pass, const_b: None }, format!("sxy2_{u}"));
        g.connect(sxy, sxy2, 0);
        g.connect(sxy2, det2, 1);
        let det = g.add_node(Op::Alu { op: AluOp::Sub, const_b: None }, format!("det{u}"));
        g.connect(det1, det, 0);
        g.connect(det2, det, 1);
        let tr = g.add_node(Op::Alu { op: AluOp::Add, const_b: None }, format!("tr{u}"));
        let sxx2 = g.add_node(Op::Alu { op: AluOp::Pass, const_b: None }, format!("sxx2_{u}"));
        g.connect(sxx, sxx2, 0);
        let syy2 = g.add_node(Op::Alu { op: AluOp::Pass, const_b: None }, format!("syy2_{u}"));
        g.connect(syy, syy2, 0);
        g.connect(sxx2, tr, 0);
        g.connect(syy2, tr, 1);
        let tr2 = g.add_node(Op::Alu { op: AluOp::Mul, const_b: None }, format!("tr2_{u}"));
        g.connect(tr, tr2, 0);
        let trp = g.add_node(Op::Alu { op: AluOp::Pass, const_b: None }, format!("trp{u}"));
        g.connect(tr, trp, 0);
        g.connect(trp, tr2, 1);
        let ktr2 = g.add_node(Op::Alu { op: AluOp::Shr, const_b: Some(4) }, format!("ktr2_{u}"));
        g.connect(tr2, ktr2, 0);
        let resp = g.add_node(Op::Alu { op: AluOp::Sub, const_b: None }, format!("resp{u}"));
        g.connect(det, resp, 0);
        g.connect(ktr2, resp, 1);
        let o = g.add_node(Op::Output { lane: u as u16, decimate: 1 }, format!("out{u}"));
        g.connect(resp, o, 0);
    }
    attach_flush(&mut g);
    App {
        name: "harris",
        kind: AppKind::Dense,
        dfg: g,
        shape: WorkloadShape::stencil(w, h, unroll),
        golden: Some("harris"),
    }
}

/// One conv5_x layer of ResNet-18 (paper Table I): 3x3 conv, 512 input and
/// 512 output channels on a 7x7 feature map, mapped weight-stationary:
/// `lanes` output-channel lanes, each an `taps`-wide MAC tree whose partial
/// sums accumulate over `time_mult` cycles per output.
pub fn resnet_conv(
    spatial: u64,
    in_ch: u64,
    out_ch: u64,
    lanes: u64,
    taps: u64,
) -> App {
    let mut g = Dfg::new();
    let time_mult = in_ch * 9 / taps; // 3x3 kernel = 9 taps per input channel
    // `taps` shared input streams (the im2col patch words), broadcast to
    // every lane — the high-fanout nets that motivate broadcast pipelining
    // (§V-B).
    let inputs: Vec<NodeId> = (0..taps)
        .map(|t| g.add_node(Op::Input { lane: t as u16 }, format!("x{t}")))
        .collect();
    for l in 0..lanes {
        let mut prods = Vec::new();
        for t in 0..taps {
            // Per-(lane, tap) weight ROM; contents are a deterministic
            // pattern standing in for trained weights.
            let wvals: Vec<i64> =
                (0..time_mult).map(|k| ((l * 7 + t * 3 + k) % 5) as i64 - 2).collect();
            let rom = g.add_node(Op::Rom { values: wvals }, format!("w{l}_{t}"));
            let mul = g.add_node(Op::Alu { op: AluOp::Mul, const_b: None }, format!("m{l}_{t}"));
            g.connect(inputs[t as usize], mul, 0);
            g.connect(rom, mul, 1);
            prods.push(mul);
        }
        let psum = crate::dfg::build::reduce_tree(&mut g, AluOp::Add, &prods, &format!("ps{l}"));
        let acc = g.add_node(Op::Accum { period: time_mult as u32 }, format!("acc{l}"));
        g.connect(psum, acc, 0);
        let o = g.add_node(
            Op::Output { lane: l as u16, decimate: time_mult as u32 },
            format!("y{l}"),
        );
        g.connect(acc, o, 0);
    }
    attach_flush(&mut g);
    App {
        name: "resnet",
        kind: AppKind::Dense,
        dfg: g,
        shape: WorkloadShape {
            frame_w: spatial,
            frame_h: out_ch,
            unroll: lanes,
            time_mult,
        },
        golden: Some("resnet"),
    }
}

/// Paper-scale ResNet conv5_x: 7x7 spatial, 512-in/512-out channels,
/// 8 lanes x 8 taps (64 multipliers; ~1.8M cycles/layer, matching the
/// Table I runtime at the pipelined frequency).
pub fn resnet_conv5x() -> App {
    resnet_conv(7 * 7, 512, 512, 8, 8)
}

/// Test-scale ResNet layer for cycle-accurate simulation.
pub fn resnet_small() -> App {
    resnet_conv(4 * 4, 8, 8, 2, 4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::interp::Interp;
    use std::collections::BTreeMap;

    #[test]
    fn gaussian_demand_matches_hand_count() {
        let app = gaussian(6400, 4800, 16);
        let (pe, mem, io) = app.dfg.tile_demand();
        // Per lane: 6 column-tap PEs + 2 line-buffer MEMs + stencil
        // arithmetic + normalize; flush adds one IO node.
        assert_eq!(mem, 32);
        assert_eq!(io, 16 + 16 + 1);
        assert!(pe <= 384, "pe = {pe}");
    }

    #[test]
    fn flush_reaches_all_stateful_nodes() {
        let app = gaussian(64, 64, 2);
        let flush = app
            .dfg
            .nodes
            .iter()
            .position(|n| matches!(n.op, Op::FlushSrc))
            .unwrap() as u32;
        let fanout = app.dfg.out_edges(flush).len();
        let stateful = app
            .dfg
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Delay { .. } | Op::Rom { .. } | Op::Accum { .. }))
            .count();
        assert_eq!(fanout, stateful);
        assert!(fanout >= 4);
        for e in app.dfg.out_edges(flush) {
            assert_eq!(app.dfg.edge(e).layer, Layer::B1);
        }
    }

    #[test]
    fn gaussian_functional_blur() {
        let app = gaussian(32, 4, 1);
        let w = 32usize;
        let input: Vec<i64> = (0..(w * 4) as i64).map(|x| (x * 5 + 1) % 97).collect();
        let mut ins = BTreeMap::new();
        ins.insert(0u16, input.clone());
        let run = Interp::run(&app.dfg, &ins, (w * 4) as u64);
        let out = &run.outputs[&0];
        // Steady state check at t >= window delay.
        let wd = crate::dfg::build::stencil_window_delay(w as u32, 3) as usize;
        let kernel = [[1i64, 2, 1], [2, 4, 2], [1, 2, 1]];
        for t in wd..w * 4 {
            let mut acc = 0i64;
            for r in 0..3 {
                for c in 0..3 {
                    acc += kernel[r][c] * input[t - (r * w + c)];
                }
            }
            assert_eq!(out[t], acc >> 4, "t={t}");
        }
    }

    #[test]
    fn camera_gamma_piecewise() {
        let app = camera(32, 4, 1);
        assert!(app.dfg.validate().is_empty(), "{:?}", app.dfg.validate());
        // Has a B1 mux-select edge.
        let b1_data = app
            .dfg
            .edges
            .iter()
            .filter(|e| e.layer == Layer::B1 && !matches!(app.dfg.node(e.src).op, Op::FlushSrc))
            .count();
        assert_eq!(b1_data, 1);
    }

    #[test]
    fn harris_is_deep() {
        let app = harris(64, 64, 1);
        // Depth of the combinational+registered chain should be large
        // (deepest dense app).
        let arr = app.dfg.arrival_cycles();
        let g_app = gaussian(64, 64, 1);
        let arr_g = g_app.dfg.arrival_cycles();
        assert!(
            arr.iter().max() > arr_g.iter().max(),
            "harris window deeper than gaussian"
        );
        let (pe, _, _) = app.dfg.tile_demand();
        assert!(pe > 40, "harris per-lane PE count {pe}");
    }

    #[test]
    fn resnet_cycle_count_matches_paper_ballpark() {
        let app = resnet_conv5x();
        // 49 * 512 / 8 lanes * 576 = 1.806M cycles; paper: 3.96ms @ 457MHz
        // = 1.81M cycles.
        assert_eq!(app.shape.steady_cycles(), 49 * 512 / 8 * (512 * 9 / 8));
        let (pe, mem, io) = app.dfg.tile_demand();
        assert!(pe <= 384 && mem <= 128);
        assert_eq!(mem, 64); // 8 lanes x 8 weight ROMs
        assert_eq!(io, 8 + 8 + 1);
    }

    #[test]
    fn resnet_broadcast_fanout() {
        let app = resnet_conv5x();
        // Each input stream feeds one multiplier per lane: fanout 8.
        let f = app.dfg.fanout_counts();
        let max_input_fanout = app
            .dfg
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.op, Op::Input { .. }))
            .map(|(i, _)| f[i])
            .max()
            .unwrap();
        assert_eq!(max_input_fanout, 8);
    }

    #[test]
    fn resnet_small_functional() {
        let app = resnet_small();
        let taps = 4usize;
        let time_mult = (8 * 9 / 4) as usize; // 18
        let n_out = 2usize;
        let cycles = 3 * time_mult;
        let mut ins = BTreeMap::new();
        for t in 0..taps {
            ins.insert(t as u16, (0..cycles as i64).map(|k| (k + t as i64) % 7).collect());
        }
        let run = Interp::run(&app.dfg, &ins, cycles as u64);
        for l in 0..n_out {
            assert_eq!(run.outputs[&(l as u16)].len(), cycles);
        }
    }

    #[test]
    fn unsharp_identity_on_flat_input() {
        // On a constant image, blur == original, so unsharp == original.
        let app = unsharp(16, 8, 1);
        let w = 16usize;
        let c = 32i64;
        let input = vec![c; w * 8];
        let mut ins = BTreeMap::new();
        ins.insert(0u16, input);
        let run = Interp::run(&app.dfg, &ins, (w * 8) as u64);
        let out = &run.outputs[&0];
        let wd = crate::dfg::build::stencil_window_delay(w as u32, 3) as usize;
        for t in (wd + 2)..w * 8 {
            assert_eq!(out[t], c, "t={t}");
        }
    }
}
