//! Compute mapping: legalize a primitive-op DFG onto PE/MEM/IO tiles
//! (the "compute mapping" stage of Fig. 2).
//!
//! The mapper runs three passes:
//!
//! 1. **Constant folding** — `Const` nodes feeding an ALU's second operand
//!    become PE immediates (`const_b`), removing the node and its net.
//! 2. **Strength reduction** — multiplies by powers of two become shifts
//!    (a shorter PE path per the delay model, mirroring what a real PE
//!    mapper does).
//! 3. **Legalization & fit check** — port-count legality, tile-kind demand
//!    vs. array capacity, and rejection of graphs the fabric cannot host.

use crate::arch::params::{ArchParams, TileKind};
use crate::dfg::ir::{AluOp, Dfg, NodeId, Op};

/// Outcome of mapping.
#[derive(Debug, Clone)]
pub struct MapReport {
    pub consts_folded: usize,
    pub muls_reduced: usize,
    pub pe_used: usize,
    pub mem_used: usize,
    pub io_used: usize,
    pub pe_capacity: usize,
    pub mem_capacity: usize,
    pub io_capacity: usize,
}

impl MapReport {
    pub fn utilization(&self) -> f64 {
        (self.pe_used + self.mem_used) as f64 / (self.pe_capacity + self.mem_capacity) as f64
    }
}

/// Mapping error.
#[derive(Debug)]
pub enum MapError {
    Invalid(Vec<String>),
    DoesNotFit { kind: TileKind, demand: usize, capacity: usize },
}

impl std::fmt::Display for MapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MapError::Invalid(v) => write!(f, "invalid DFG: {}", v.join("; ")),
            MapError::DoesNotFit { kind, demand, capacity } => {
                write!(f, "{kind:?} demand {demand} exceeds capacity {capacity}")
            }
        }
    }
}

impl std::error::Error for MapError {}

/// Fold `Const` nodes into consumer immediates where the consumer is an ALU
/// reading the constant on port 1 (the PE immediate slot).
pub fn fold_constants(g: &mut Dfg) -> usize {
    let mut folded = 0;
    loop {
        let mut change: Option<(NodeId, NodeId, i64)> = None;
        'search: for (i, n) in g.nodes.iter().enumerate() {
            if let Op::Const { value } = n.op {
                for e in &g.edges {
                    if e.src == i as NodeId && e.dst_port == 1 {
                        let foldable = match &g.nodes[e.dst as usize].op {
                            Op::Alu { const_b: None, .. } => true,
                            // A compound's port-1 operand feeds its head.
                            Op::Fused { ops } => ops[0].const_b.is_none(),
                            _ => false,
                        };
                        if foldable {
                            change = Some((i as NodeId, e.dst, value));
                            break 'search;
                        }
                    }
                }
            }
        }
        let Some((cnode, consumer, value)) = change else { break };
        match &mut g.node_mut(consumer).op {
            Op::Alu { const_b, .. } => *const_b = Some(value),
            Op::Fused { ops } => ops[0].const_b = Some(value),
            _ => unreachable!(),
        }
        g.edges.retain(|e| !(e.src == cnode && e.dst == consumer && e.dst_port == 1));
        folded += 1;
        // Remove the const node entirely if it has no remaining fanout.
        if g.out_edges(cnode).is_empty() {
            remove_node(g, cnode);
        }
    }
    folded
}

/// Remove a node and compact ids (no dangling edges allowed).
fn remove_node(g: &mut Dfg, id: NodeId) {
    assert!(g.out_edges(id).is_empty() && g.in_edges(id).is_empty());
    g.nodes.remove(id as usize);
    for e in &mut g.edges {
        if e.src > id {
            e.src -= 1;
        }
        if e.dst > id {
            e.dst -= 1;
        }
    }
}

/// Replace `x * 2^k` (immediate) with `x << k`.
pub fn strength_reduce(g: &mut Dfg) -> usize {
    let mut n = 0;
    for node in &mut g.nodes {
        if let Op::Alu { op: op @ AluOp::Mul, const_b: Some(c) } = &mut node.op {
            if *c > 0 && (*c & (*c - 1)) == 0 {
                let k = (*c as u64).trailing_zeros() as i64;
                *op = AluOp::Shl;
                *c = k;
                n += 1;
            }
        }
    }
    n
}

/// Run the full mapping pipeline. Mutates the graph in place and returns a
/// report, or an error if the application cannot be legalized onto the
/// array.
pub fn map_dfg(g: &mut Dfg, arch: &ArchParams) -> Result<MapReport, MapError> {
    let consts_folded = fold_constants(g);
    let muls_reduced = strength_reduce(g);

    let problems = g.validate();
    if !problems.is_empty() {
        return Err(MapError::Invalid(problems));
    }

    let (pe_used, mem_used, io_used) = g.tile_demand();
    let (pe_cap, mem_cap) = arch.core_tile_counts();
    // IO tiles host up to two IO nodes (one per slot).
    let io_cap = 2 * arch.cols;
    for (kind, demand, capacity) in [
        (TileKind::Pe, pe_used, pe_cap),
        (TileKind::Mem, mem_used, mem_cap),
        (TileKind::Io, io_used, io_cap),
    ] {
        if demand > capacity {
            return Err(MapError::DoesNotFit { kind, demand, capacity });
        }
    }

    Ok(MapReport {
        consts_folded,
        muls_reduced,
        pe_used,
        mem_used,
        io_used,
        pe_capacity: pe_cap,
        mem_capacity: mem_cap,
        io_capacity: io_cap,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::ir::{AluOp, Dfg, Op};

    #[test]
    fn folds_constants_into_immediates() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let c = g.add_node(Op::Const { value: 7 }, "c7");
        let a = g.add_node(Op::Alu { op: AluOp::Add, const_b: None }, "add");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, a, 0);
        g.connect(c, a, 1);
        g.connect(a, o, 0);
        let folded = fold_constants(&mut g);
        assert_eq!(folded, 1);
        assert_eq!(g.nodes.len(), 3); // const removed
        let add = g
            .nodes
            .iter()
            .find_map(|n| match &n.op {
                Op::Alu { const_b, .. } => Some(*const_b),
                _ => None,
            })
            .unwrap();
        assert_eq!(add, Some(7));
        assert!(g.validate().is_empty());
    }

    #[test]
    fn folds_constant_into_fused_head_immediate() {
        use crate::dfg::ir::FusedStep;
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let c = g.add_node(Op::Const { value: 5 }, "c5");
        let f = g.add_node(
            Op::Fused {
                ops: vec![
                    FusedStep { op: AluOp::Sub, const_b: None },
                    FusedStep { op: AluOp::Abs, const_b: None },
                ],
            },
            "sub+abs",
        );
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, f, 0);
        g.connect(c, f, 1);
        g.connect(f, o, 0);
        assert_eq!(fold_constants(&mut g), 1);
        assert_eq!(g.nodes.len(), 3); // const removed
        let head_cb = g
            .nodes
            .iter()
            .find_map(|n| match &n.op {
                Op::Fused { ops } => Some(ops[0].const_b),
                _ => None,
            })
            .unwrap();
        assert_eq!(head_cb, Some(5));
        assert!(g.validate().is_empty());
    }

    #[test]
    fn shared_const_folds_into_all_consumers() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let c = g.add_node(Op::Const { value: 3 }, "c");
        let a1 = g.add_node(Op::Alu { op: AluOp::Add, const_b: None }, "a1");
        let a2 = g.add_node(Op::Alu { op: AluOp::Mul, const_b: None }, "a2");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, a1, 0);
        g.connect(c, a1, 1);
        g.connect(i, a2, 0);
        g.connect(c, a2, 1);
        g.connect(a1, o, 0);
        // a2 dangles into nothing — wire it to keep validate quiet.
        let o2 = g.add_node(Op::Output { lane: 1, decimate: 1 }, "o2");
        g.connect(a2, o2, 0);
        assert_eq!(fold_constants(&mut g), 2);
        assert!(g.validate().is_empty());
    }

    #[test]
    fn strength_reduction() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let m = g.add_node(Op::Alu { op: AluOp::Mul, const_b: Some(8) }, "m8");
        let m2 = g.add_node(Op::Alu { op: AluOp::Mul, const_b: Some(6) }, "m6");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        let o2 = g.add_node(Op::Output { lane: 1, decimate: 1 }, "o2");
        g.connect(i, m, 0);
        g.connect(i, m2, 0);
        g.connect(m, o, 0);
        g.connect(m2, o2, 0);
        assert_eq!(strength_reduce(&mut g), 1);
        assert!(matches!(g.node(m).op, Op::Alu { op: AluOp::Shl, const_b: Some(3) }));
        assert!(matches!(g.node(m2).op, Op::Alu { op: AluOp::Mul, const_b: Some(6) }));
    }

    #[test]
    fn fit_check_rejects_oversized() {
        let arch = ArchParams::tiny(2, 4); // 6 PE + 2 MEM
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let mut prev = i;
        for k in 0..10 {
            let a = g.add_node(Op::Alu { op: AluOp::Add, const_b: Some(1) }, format!("a{k}"));
            g.connect(prev, a, 0);
            prev = a;
        }
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(prev, o, 0);
        let err = map_dfg(&mut g, &arch).unwrap_err();
        assert!(matches!(err, MapError::DoesNotFit { kind: TileKind::Pe, .. }));
    }

    #[test]
    fn map_reports_utilization() {
        let arch = ArchParams::paper();
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let a = g.add_node(Op::Alu { op: AluOp::Add, const_b: Some(1) }, "a");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, a, 0);
        g.connect(a, o, 0);
        let r = map_dfg(&mut g, &arch).unwrap();
        assert_eq!(r.pe_used, 1);
        assert_eq!(r.io_used, 2);
        assert!(r.utilization() > 0.0 && r.utilization() < 0.01);
    }
}
