//! Post-place-and-route pipelining (paper §V-D, Fig. 5).
//!
//! After PnR we know exactly where every net is routed. This pass
//! iteratively: (1) runs application STA, (2) picks the critical segment,
//! (3) enables the switch-box pipelining register nearest the middle of
//! that segment, (4) updates the logical per-edge register counts of every
//! DFG edge the new register delays, (5) branch-delay-matches the graph and
//! re-realizes the balancing registers. It stops when the critical segment
//! no longer crosses breakable interconnect, when an iteration fails to
//! improve the clock period, or when the iteration cap is reached.
//!
//! For sparse applications the same loop applies, but the register becomes
//! a FIFO stage covering the data/valid/ready triple (§VII): the companion
//! nets are registered at the geometrically matching hop, and no branch
//! delay matching is needed (the interface is elastic).


use crate::arch::canal::{InterconnectGraph, NodeId as RrgNode, NodeKind};
use crate::dfg::ir::EdgeId;
use crate::pnr::{IncrementalCfg, RoutedDesign};
use crate::timing::sta::{analyze, StaEngine};

use super::bdm::branch_delay_match;

/// Post-PnR pipelining knobs.
#[derive(Debug, Clone)]
pub struct PostPnrParams {
    /// Iteration cap.
    pub max_iters: usize,
    /// Stop when relative period improvement falls below this for an
    /// iteration (the move is rolled back).
    pub min_gain: f64,
}

impl Default for PostPnrParams {
    fn default() -> Self {
        PostPnrParams { max_iters: 200, min_gain: 1e-4 }
    }
}

/// Result of the pass.
#[derive(Debug, Clone)]
pub struct PostPnrReport {
    pub iters: usize,
    pub regs_enabled: usize,
    pub period_before_ps: f64,
    pub period_after_ps: f64,
}

/// Map a DFG edge to the sinks its route shares a node with.
fn edges_through_node(d: &RoutedDesign, node: RrgNode) -> Vec<EdgeId> {
    let mut out = Vec::new();
    for ei in 0..d.dfg.edges.len() {
        if let Some(path) = d.edge_path(ei as EdgeId) {
            if path.contains(&node) {
                out.push(ei as EdgeId);
            }
        }
    }
    out
}

/// Find which net (and kind) a route node belongs to.
fn owning_net(d: &RoutedDesign, node: RrgNode) -> Option<usize> {
    for (ni, r) in d.routes.iter().enumerate() {
        if r.sink_paths.iter().any(|p| p.contains(&node)) {
            return Some(ni);
        }
    }
    None
}

/// For a companion (valid/ready) net node choice, find the geometrically
/// matching hop index on a path.
fn middle_unregistered_sbout(
    d: &RoutedDesign,
    graph: &InterconnectGraph,
    nodes: &[RrgNode],
) -> Option<RrgNode> {
    let cands: Vec<RrgNode> = nodes
        .iter()
        .copied()
        .filter(|&n| {
            matches!(graph.decode(n).kind, NodeKind::SbOut { .. }) && !d.sb_regs.contains(&n)
        })
        .collect();
    if cands.is_empty() {
        None
    } else {
        Some(cands[cands.len() / 2])
    }
}

/// Run post-PnR pipelining on a dense (statically scheduled) design.
pub fn postpnr_pipelining(
    d: &mut RoutedDesign,
    graph: &InterconnectGraph,
    p: &PostPnrParams,
) -> PostPnrReport {
    // This loop runs ~2 STA passes per enabled register; the incremental
    // engine memoizes the propagation across them (bit-identical results,
    // see `StaEngine`). `--no-incremental` falls back to full passes.
    let mut engine = IncrementalCfg::current().sta.then(|| StaEngine::new(d));
    let mut sta = |d: &RoutedDesign| match engine.as_mut() {
        Some(e) => e.analyze(d, graph),
        None => analyze(d, graph),
    };

    let initial = sta(d);
    let mut best_period = initial.period_ps;
    let mut regs_enabled = 0usize;
    let mut iters = 0usize;

    while iters < p.max_iters {
        iters += 1;
        let cp = sta(d);
        let Some(target) = middle_unregistered_sbout(d, graph, &cp.segment.nodes) else {
            break; // core-internal or unbreakable segment
        };
        // The flush broadcast must reach every destination on the same
        // cycle; it cannot be pipelined in the interconnect (that is the
        // §VI motivation for hardening it). If it has become the critical
        // path, software pipelining is done.
        if let Some(ni) = owning_net(d, target) {
            if d.nets[ni].kind == crate::pnr::netlist::NetKind::Flush {
                break;
            }
        }

        // Snapshot for rollback.
        let snap_regs: Vec<u32> = d.dfg.edges.iter().map(|e| e.regs).collect();
        let snap_fifos: Vec<u32> = d.dfg.edges.iter().map(|e| e.fifos).collect();
        let snap_sb = d.sb_regs.clone();
        let snap_pin = d.pinned_regs.clone();
        let snap_rf = d.rf_delay.clone();

        let sparse = d.is_sparse_app();
        if sparse {
            enable_fifo_break(d, graph, target);
        } else {
            enable_register_break(d, graph, target);
        }

        let after = sta(d);
        if after.period_ps < best_period * (1.0 - p.min_gain) {
            best_period = after.period_ps;
            regs_enabled += 1;
        } else {
            // Roll back and stop: the critical path can no longer be
            // improved by breaking interconnect.
            for (ei, r) in snap_regs.into_iter().enumerate() {
                d.dfg.edges[ei].regs = r;
            }
            for (ei, f) in snap_fifos.into_iter().enumerate() {
                d.dfg.edges[ei].fifos = f;
            }
            d.sb_regs = snap_sb;
            d.pinned_regs = snap_pin;
            d.rf_delay = snap_rf;
            break;
        }
    }

    PostPnrReport {
        iters,
        regs_enabled,
        period_before_ps: initial.period_ps,
        period_after_ps: best_period,
    }
}

/// Dense break: enable + pin `target`, bump logical regs of delayed edges,
/// BDM, re-realize.
fn enable_register_break(d: &mut RoutedDesign, graph: &InterconnectGraph, target: RrgNode) {
    d.sb_regs.insert(target);
    d.pinned_regs.insert(target);
    for ei in edges_through_node(d, target) {
        d.dfg.edge_mut(ei).regs += 1;
    }
    branch_delay_match(&mut d.dfg);
    d.realize_registers(graph);
}

/// Sparse break: a FIFO stage across the data/valid/ready triple (§VII).
/// The data hop gets a pinned register; each companion net gets a pinned
/// register at its geometrically matching hop; affected edges count a FIFO
/// stage (elastic — no BDM).
fn enable_fifo_break(d: &mut RoutedDesign, graph: &InterconnectGraph, target: RrgNode) {
    // The target may already be on a companion net; normalize to the
    // owning net.
    let Some(ni) = owning_net(d, target) else {
        return;
    };
    // Resolve the data net of the triple.
    let data_ni = d.nets[ni].companion_of.unwrap_or(ni);
    d.sb_regs.insert(target);
    d.pinned_regs.insert(target);
    // Register the other members of the triple mid-path.
    let triple: Vec<usize> = d
        .nets
        .iter()
        .filter(|n| {
            n.id != ni && (n.id == data_ni || n.companion_of == Some(data_ni))
        })
        .map(|n| n.id)
        .collect();
    let mut to_pin: Vec<RrgNode> = Vec::new();
    for tni in triple {
        for path in &d.routes[tni].sink_paths {
            if let Some(n) = middle_unregistered_sbout(d, graph, path) {
                to_pin.push(n);
            }
        }
    }
    for n in to_pin {
        d.sb_regs.insert(n);
        d.pinned_regs.insert(n);
    }
    // FIFO stage on the data edges (latency bookkeeping; elastic).
    let edges = d.nets[data_ni].edges.clone();
    for ei in edges {
        d.dfg.edge_mut(ei).fifos += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::delay::{DelayLib, DelayModelParams};
    use crate::arch::params::ArchParams;
    use crate::pipeline::compute::compute_pipelining;
    use crate::pnr::{place_and_route, PlaceParams, RouteParams};

    fn build(app: &crate::apps::App, seed: u64) -> (RoutedDesign, InterconnectGraph) {
        // Post-PnR evaluation in the paper has the hardware flush
        // hardening applied (§VIII-B); the routed flush broadcast is
        // unbreakable and would otherwise cap these small test apps.
        let arch = crate::pipeline::flush::harden(&ArchParams::paper());
        let lib = DelayLib::generate(&arch, &DelayModelParams::default());
        let mut graph = InterconnectGraph::build(&arch);
        graph.annotate_delays(&lib);
        let d = place_and_route(
            &app.dfg,
            &arch,
            &graph,
            &lib,
            &PlaceParams::baseline(seed),
            &RouteParams::default(),
        )
        .unwrap();
        (d, graph)
    }

    #[test]
    fn postpnr_improves_compute_pipelined_design() {
        let mut app = crate::apps::dense::gaussian(64, 64, 1);
        compute_pipelining(&mut app.dfg);
        let (mut d, graph) = build(&app, 3);
        let rep = postpnr_pipelining(&mut d, &graph, &PostPnrParams::default());
        assert!(
            rep.period_after_ps < rep.period_before_ps,
            "no improvement: {} -> {}",
            rep.period_before_ps,
            rep.period_after_ps
        );
        assert!(rep.regs_enabled > 0);
        // Registers stay consistent and balanced.
        d.registers_consistent().unwrap();
        assert!(crate::pipeline::bdm::check_balanced(&d.dfg).is_empty());
    }

    #[test]
    fn postpnr_monotone_never_worse() {
        let mut app = crate::apps::dense::unsharp(64, 64, 1);
        compute_pipelining(&mut app.dfg);
        let (mut d, graph) = build(&app, 5);
        let before = analyze(&d, &graph).period_ps;
        let rep = postpnr_pipelining(&mut d, &graph, &PostPnrParams::default());
        let after = analyze(&d, &graph).period_ps;
        assert!(after <= before, "rollback failed: {before} -> {after}");
        assert!((after - rep.period_after_ps).abs() < 1e-6);
    }

    #[test]
    fn postpnr_respects_iteration_cap() {
        let mut app = crate::apps::dense::harris(64, 64, 1);
        compute_pipelining(&mut app.dfg);
        let (mut d, graph) = build(&app, 7);
        let rep = postpnr_pipelining(
            &mut d,
            &graph,
            &PostPnrParams { max_iters: 3, ..Default::default() },
        );
        assert!(rep.iters <= 3);
    }

    #[test]
    fn sparse_postpnr_uses_fifos_not_bdm_regs() {
        let app = crate::apps::sparse::vec_elemadd(1024, 0.2);
        let (mut d, graph) = build(&app, 9);
        let before_fifos: u64 = d.dfg.edges.iter().map(|e| e.fifos as u64).sum();
        let rep = postpnr_pipelining(&mut d, &graph, &PostPnrParams::default());
        let after_fifos: u64 = d.dfg.edges.iter().map(|e| e.fifos as u64).sum();
        if rep.regs_enabled > 0 {
            assert!(after_fifos > before_fifos, "sparse breaks must add FIFO stages");
        }
        // No balancing registers were added to sparse edges.
        for e in &d.dfg.edges {
            if d.dfg.node(e.dst).is_sparse() {
                assert_eq!(e.regs, 0);
            }
        }
    }
}
