//! Low unrolling duplication (paper §V-E).
//!
//! Unrolled applications give the placer a large, hard problem. This pass
//! instead places-and-routes a *low-unroll* version of the application in a
//! narrow column region of the array, then duplicates the resulting tile
//! and interconnect configuration across the array — "unrolling the
//! application in the exact same way every time". The placer solves a much
//! smaller problem (shorter routes, shorter critical path) while the full
//! array still produces `unroll` pixels per cycle.
//!
//! Duplication is horizontal in column strides that are a multiple of the
//! MEM-column period, so every feature lands on a tile of the same kind;
//! the duplicated regions are electrically identical, so the critical path
//! of the full array equals the critical path of the region design (clock
//! skew is already budgeted globally by the STA margin).

use crate::arch::bitstream::{Bitstream, ConfigSpace};
use crate::arch::params::{ArchParams, TileCoord};
use crate::dfg::ir::Dfg;
use crate::schedule::WorkloadShape;

/// Outcome of region sizing.
#[derive(Debug, Clone)]
pub struct DupPlan {
    /// Columns per region (multiple of `mem_col_period`).
    pub region_cols: usize,
    /// Number of stamped copies across the array.
    pub copies: usize,
    /// Lanes built inside one region.
    pub lanes_per_copy: u64,
}

/// Plan a duplication: find the narrowest column region (a multiple of the
/// MEM period) that fits one or more lanes such that
/// `copies * lanes_per_copy >= unroll`. Returns `None` if even the full
/// array cannot host a single lane group.
pub fn plan_duplication(lane_dfg: &Dfg, unroll: u64, arch: &ArchParams) -> Option<DupPlan> {
    let (lane_pe, lane_mem, lane_io) = lane_dfg.tile_demand();
    let period = arch.mem_col_period;
    for region_cols in (period..=arch.cols).step_by(period) {
        let copies = arch.cols / region_cols;
        let lanes_per_copy = unroll.div_ceil(copies as u64);
        // Region capacity.
        let mem_cols = (0..region_cols).filter(|x| (x + 1) % period == 0).count();
        let pe_cap = (region_cols - mem_cols) * arch.rows;
        let mem_cap = mem_cols * arch.rows;
        let io_cap = region_cols * 2;
        // Demand for `lanes_per_copy` lanes (flush source shared; count it
        // once via ceiling on IO).
        let fits = lane_pe * lanes_per_copy as usize <= pe_cap * 85 / 100
            && lane_mem * lanes_per_copy as usize <= mem_cap
            && lane_io * lanes_per_copy as usize + 1 <= io_cap;
        if fits {
            return Some(DupPlan {
                region_cols,
                copies,
                lanes_per_copy,
            });
        }
    }
    None
}

/// The placement region for the duplication plan.
pub fn region_of(plan: &DupPlan, arch: &ArchParams) -> (TileCoord, (usize, usize)) {
    (TileCoord::new(0, 1), (plan.region_cols, arch.rows))
}

/// Stamp a region's configuration across the array (bitstream-level
/// duplication). Returns the number of copies written (including the
/// original).
pub fn stamp_bitstream(
    bs: &mut Bitstream,
    plan: &DupPlan,
    arch: &ArchParams,
    cs: &ConfigSpace,
) -> usize {
    for copy in 1..plan.copies {
        let dst = TileCoord::new(copy * plan.region_cols, 0);
        bs.duplicate_region(
            arch,
            cs,
            TileCoord::new(0, 0),
            (plan.region_cols, arch.grid_rows()),
            dst,
        );
    }
    plan.copies
}

/// The workload shape of the full duplicated application given the region
/// shape (throughput scales with the stamped copies).
pub fn full_shape(region_shape: &WorkloadShape, plan: &DupPlan) -> WorkloadShape {
    WorkloadShape {
        frame_w: region_shape.frame_w,
        frame_h: region_shape.frame_h,
        unroll: region_shape.unroll * plan.copies as u64,
        time_mult: region_shape.time_mult,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_16_plans_compactly() {
        let arch = ArchParams::paper();
        let lane = crate::apps::dense::gaussian(64, 64, 1);
        let plan = plan_duplication(&lane.dfg, 16, &arch).expect("plan");
        assert_eq!(plan.region_cols % arch.mem_col_period, 0);
        assert!(plan.copies >= 2, "{plan:?}");
        assert!(plan.copies * plan.lanes_per_copy as usize >= 16);
        // The region is much narrower than the array.
        assert!(plan.region_cols <= arch.cols / 2);
    }

    #[test]
    fn oversized_lane_returns_none_or_full_width() {
        let arch = ArchParams::paper();
        // harris with unroll 4 in one region: heavy; plan must still cover
        // demand or bail out.
        let lane = crate::apps::dense::harris(64, 64, 1);
        if let Some(plan) = plan_duplication(&lane.dfg, 4, &arch) {
            let (pe, _, _) = lane.dfg.tile_demand();
            let mem_cols =
                (0..plan.region_cols).filter(|x| (x + 1) % arch.mem_col_period == 0).count();
            let pe_cap = (plan.region_cols - mem_cols) * arch.rows;
            assert!(pe * plan.lanes_per_copy as usize <= pe_cap);
        }
    }

    #[test]
    fn stamping_duplicates_all_columns() {
        let arch = ArchParams::paper();
        let cs = ConfigSpace::new(&arch);
        let mut bs = Bitstream::new();
        use crate::arch::bitstream::Feature;
        // Mark one PE in the region.
        bs.set(&arch, &cs, TileCoord::new(1, 2), Feature::PeOp, 9);
        let plan = DupPlan { region_cols: 8, copies: 4, lanes_per_copy: 1 };
        let n = stamp_bitstream(&mut bs, &plan, &arch, &cs);
        assert_eq!(n, 4);
        for copy in 0..4 {
            assert_eq!(
                bs.get(&arch, &cs, TileCoord::new(1 + 8 * copy, 2), Feature::PeOp),
                9,
                "copy {copy}"
            );
        }
    }

    #[test]
    fn full_shape_scales_unroll() {
        let shape = WorkloadShape::stencil(640, 480, 2);
        let plan = DupPlan { region_cols: 8, copies: 4, lanes_per_copy: 2 };
        let f = full_shape(&shape, &plan);
        assert_eq!(f.unroll, 8);
        assert_eq!(f.steady_cycles() * 4, shape.steady_cycles());
    }
}
