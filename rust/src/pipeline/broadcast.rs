//! Broadcast signal pipelining (paper §V-B).
//!
//! Nets with one source and many destinations route inefficiently and,
//! once compute pipelining has registered every PE, dominate the critical
//! path ("in our benchmark suite, every application has a broadcast path").
//! This pass rebuilds high-fanout connections as trees of registered
//! buffer PEs, bounding the fanout (and therefore the wirelength) of every
//! stage. The trade-off knobs are the maximum fanout per tree node and the
//! register budget; branch delay matching restores correctness afterwards.

use crate::dfg::ir::{AluOp, Dfg, NodeId, Op};

use super::bdm::branch_delay_match;

/// Broadcast pipelining knobs (§V-B: "the parameters of this transformation
/// pass (number of tree levels, maximum number of pipeline registers, etc.)
/// can be adjusted").
#[derive(Debug, Clone)]
pub struct BroadcastParams {
    /// Fanout threshold above which a net is treated as a broadcast.
    pub fanout_threshold: usize,
    /// Maximum fanout of each tree stage after the transform.
    pub max_stage_fanout: usize,
    /// Maximum buffer PEs to spend per net.
    pub max_buffers_per_net: usize,
}

impl Default for BroadcastParams {
    fn default() -> Self {
        BroadcastParams { fanout_threshold: 4, max_stage_fanout: 4, max_buffers_per_net: 16 }
    }
}

/// Apply broadcast pipelining. Returns the number of buffer PEs inserted.
/// Flush nets are excluded (handled by hardware hardening, §VI); sparse
/// nets are excluded (the paper found no effect, §VIII-D).
pub fn broadcast_pipelining(g: &mut Dfg, p: &BroadcastParams) -> usize {
    let mut inserted = 0;
    // Snapshot driver list; we add nodes while iterating.
    let num_nodes = g.nodes.len();
    for src in 0..num_nodes as NodeId {
        if matches!(g.node(src).op, Op::FlushSrc) {
            continue;
        }
        if g.node(src).is_sparse() {
            continue;
        }
        loop {
            // Out-edges on the data layer only, excluding edges to sparse
            // sinks.
            let outs: Vec<usize> = g
                .edges
                .iter()
                .enumerate()
                .filter(|(_, e)| {
                    e.src == src
                        && e.layer == crate::arch::canal::Layer::B16
                        && !g.node(e.dst).is_sparse()
                })
                .map(|(ei, _)| ei)
                .collect();
            if outs.len() <= p.fanout_threshold || inserted >= p.max_buffers_per_net {
                break;
            }
            // Group sinks into chunks of max_stage_fanout, each behind a
            // registered Pass buffer.
            let mut made_progress = false;
            for chunk in outs.chunks(p.max_stage_fanout) {
                if chunk.len() < 2 || inserted >= p.max_buffers_per_net {
                    continue;
                }
                let buf = g.add_node(
                    Op::Alu { op: AluOp::Pass, const_b: None },
                    format!("bcast{src}_{inserted}"),
                );
                g.node_mut(buf).input_regs = true; // registered buffer stage
                for &ei in chunk {
                    g.edges[ei].src = buf;
                }
                g.connect(src, buf, 0);
                inserted += 1;
                made_progress = true;
            }
            if !made_progress {
                break;
            }
        }
    }
    if inserted > 0 {
        branch_delay_match(g);
    }
    inserted
}

/// Maximum data-layer fanout in the graph (diagnostic).
pub fn max_fanout(g: &Dfg) -> usize {
    let mut counts = std::collections::HashMap::new();
    for e in &g.edges {
        if e.layer == crate::arch::canal::Layer::B16 {
            *counts.entry(e.src).or_insert(0usize) += 1;
        }
    }
    counts.values().copied().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::interp::Interp;
    use std::collections::BTreeMap;

    fn star(n: usize) -> Dfg {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        for k in 0..n {
            let a =
                g.add_node(Op::Alu { op: AluOp::Add, const_b: Some(k as i64) }, format!("a{k}"));
            g.connect(i, a, 0);
            let o = g.add_node(Op::Output { lane: k as u16, decimate: 1 }, format!("o{k}"));
            g.connect(a, o, 0);
        }
        g
    }

    #[test]
    fn reduces_fanout_below_threshold() {
        let mut g = star(12);
        assert_eq!(max_fanout(&g), 12);
        let p = BroadcastParams::default();
        let n = broadcast_pipelining(&mut g, &p);
        assert!(n > 0);
        assert!(max_fanout(&g) <= p.max_stage_fanout.max(p.fanout_threshold));
        assert!(g.validate().is_empty(), "{:?}", g.validate());
        assert!(super::super::bdm::check_balanced(&g).is_empty());
    }

    #[test]
    fn preserves_function_with_uniform_shift() {
        let input: Vec<i64> = (0..24).map(|x| x * 3 % 17).collect();
        let mut ins = BTreeMap::new();
        ins.insert(0u16, input);
        let g0 = star(9);
        let r0 = Interp::run(&g0, &ins, 24);
        let mut g1 = star(9);
        broadcast_pipelining(&mut g1, &BroadcastParams::default());
        let r1 = Interp::run(&g1, &ins, 24);
        // Each lane shifts by its own (schedule-known) latency: outputs are
        // independent endpoints, so uniformity is not required — the static
        // schedule tracks per-output offsets (§V-F).
        let arr1 = g1.arrival_cycles();
        let mut checked = 0;
        for (i, n) in g1.nodes.iter().enumerate() {
            if let Op::Output { lane, .. } = n.op {
                let s = arr1[i] as usize;
                let a = &r0.outputs[&lane];
                let b = &r1.outputs[&lane];
                assert_eq!(&a[..24 - s], &b[s..], "lane {lane} shift {s}");
                checked += 1;
            }
        }
        assert_eq!(checked, 9);
        // At least one lane goes through a registered buffer.
        assert!(
            g1.nodes
                .iter()
                .enumerate()
                .any(|(i, n)| matches!(n.op, Op::Output { .. }) && arr1[i] > 0)
        );
    }

    #[test]
    fn small_fanout_untouched() {
        let mut g = star(3);
        let n = broadcast_pipelining(&mut g, &BroadcastParams::default());
        assert_eq!(n, 0);
    }

    #[test]
    fn flush_excluded() {
        let app = crate::apps::dense::gaussian(256, 4, 1);
        let mut g = app.dfg;
        let flush = g.nodes.iter().position(|n| matches!(n.op, Op::FlushSrc)).unwrap() as NodeId;
        let before = g.out_edges(flush).len();
        broadcast_pipelining(&mut g, &BroadcastParams::default());
        assert_eq!(g.out_edges(flush).len(), before);
    }

    #[test]
    fn resnet_broadcasts_get_trees() {
        let app = crate::apps::dense::resnet_conv5x();
        let mut g = app.dfg;
        let before = max_fanout(&g);
        assert!(before >= 8);
        let n = broadcast_pipelining(&mut g, &BroadcastParams::default());
        assert!(n > 0);
        assert!(max_fanout(&g) < before);
        assert!(g.validate().is_empty());
    }
}
