//! Hardware flush hardening (paper §VI).
//!
//! The flush signal synchronizes every stateful tile at application start.
//! It is a broadcast with one source and potentially hundreds of
//! destinations; pipelining it with the §V-B tree transform would burn a
//! huge number of registers, so the paper *hardens* it: a dedicated wire
//! outside the configurable interconnect, routed from the top of the array
//! down each column.
//!
//! In this model, hardening is an architecture flag
//! (`ArchParams::hardened_flush`): the netlist extractor then omits the
//! flush net entirely, so it neither consumes interconnect resources nor
//! appears in STA. The dedicated network's own timing is modeled here and
//! asserted (in tests and in `timing` reporting) to be far from critical:
//! the column spine is buffered at every tile boundary, so its worst
//! register-to-register segment is one vertical tile crossing plus a
//! buffer.

use crate::arch::delay::DelayLib;
use crate::arch::params::{ArchParams, TileKind};

/// Worst-case register-to-register segment of the hardened flush network:
/// one vertical MEM-tile crossing plus a repeater, plus margins.
pub fn hardened_flush_segment_ps(lib: &DelayLib) -> f64 {
    // One vertical crossing of the tallest tile + buffer + clocking
    // overheads. Uses the same component model as the interconnect.
    let model = lib.model();
    let tallest = model.pe_dims_um.1.max(model.mem_dims_um.1);
    lib.clk_q_ps() as f64
        + tallest * model.wire_ps_per_um
        + 2.0 * model.mux2_ps
        + lib.setup_ps() as f64
}

/// Return a copy of the architecture with the flush network hardened.
pub fn harden(arch: &ArchParams) -> ArchParams {
    ArchParams { hardened_flush: true, ..arch.clone() }
}

/// Count of flush destinations in an application (the fanout the hardened
/// network absorbs) — stateful tiles only.
pub fn flush_destinations(g: &crate::dfg::ir::Dfg) -> usize {
    g.nodes
        .iter()
        .filter(|n| {
            matches!(
                n.op,
                crate::dfg::ir::Op::Delay { .. }
                    | crate::dfg::ir::Op::Rom { .. }
                    | crate::dfg::ir::Op::Accum { .. }
            ) || (n.is_sparse() && n.tile_kind() == TileKind::Mem)
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::delay::DelayModelParams;

    #[test]
    fn hardened_flush_never_limits_high_frequencies() {
        let arch = ArchParams::paper();
        let lib = DelayLib::generate(&arch, &DelayModelParams::default());
        let seg = hardened_flush_segment_ps(&lib);
        // Must support > 1 GHz so it never bounds the paper's 457-617 MHz
        // pipelined applications.
        assert!(seg < 1000.0, "hardened flush segment {seg} ps");
    }

    #[test]
    fn harden_flag() {
        let arch = ArchParams::paper();
        assert!(!arch.hardened_flush);
        assert!(harden(&arch).hardened_flush);
    }

    #[test]
    fn flush_fanout_grows_with_unroll() {
        let a1 = crate::apps::dense::gaussian(256, 64, 1);
        let a4 = crate::apps::dense::gaussian(256, 64, 4);
        assert!(flush_destinations(&a4.dfg) > flush_destinations(&a1.dfg));
    }
}
