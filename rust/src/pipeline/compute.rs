//! Compute pipelining (paper §V-A, Fig. 4 left) and the register-chain to
//! register-file transform (Fig. 4 right).

use crate::dfg::ir::{Dfg, Op};

use super::bdm::branch_delay_match;

/// Enable every available PE input register, then branch-delay-match.
/// Returns (PEs pipelined, balancing registers added).
pub fn compute_pipelining(g: &mut Dfg) -> (usize, u64) {
    let mut pes = 0;
    for node in &mut g.nodes {
        if matches!(node.op, Op::Alu { .. } | Op::Fused { .. }) && !node.input_regs {
            node.input_regs = true;
            pes += 1;
        }
    }
    let regs = branch_delay_match(g);
    (pes, regs)
}

/// Transform long chains of balancing registers into register-file
/// variable-length shift registers (paper §V-A): every edge carrying at
/// least `threshold` pipeline registers gets them replaced by a `Delay`
/// node (realized in a PE register file), freeing interconnect registers.
/// Returns the number of chains transformed.
pub fn regfile_transform(g: &mut Dfg, threshold: u32) -> usize {
    assert!(threshold >= 2, "a chain is at least 2 registers");
    let mut transformed = 0;
    let ne = g.edges.len();
    for ei in 0..ne {
        let e = &g.edges[ei];
        if e.regs < threshold || e.fifos > 0 {
            continue;
        }
        let (src, dst, port, layer, regs) = (e.src, e.dst, e.dst_port, e.layer, e.regs);
        // Replace edge registers with a Delay node of the same cycle count.
        let d = g.add_node(Op::Delay { cycles: regs, pipelined: true }, format!("rfchain{ei}"));
        g.edges[ei].regs = 0;
        g.edges[ei].dst = d;
        g.edges[ei].dst_port = 0;
        let new_e = g.add_edge(d, dst, port, layer);
        let _ = (src, new_e);
        transformed += 1;
    }
    transformed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::interp::Interp;
    use crate::dfg::ir::AluOp;
    use std::collections::BTreeMap;

    #[test]
    fn compute_pipelining_sets_all_pes() {
        let app = crate::apps::dense::gaussian(64, 64, 1);
        let mut g = app.dfg;
        let (pes, _regs) = compute_pipelining(&mut g);
        assert!(pes > 0);
        for n in &g.nodes {
            if matches!(n.op, Op::Alu { .. }) {
                assert!(n.input_regs);
            }
        }
        assert!(super::super::bdm::check_balanced(&g).is_empty());
    }

    #[test]
    fn compute_pipelining_preserves_function() {
        let app = crate::apps::dense::gaussian(32, 8, 1);
        let mut g = app.dfg.clone();
        let input: Vec<i64> = (0..256).map(|x| (x * 7 + 5) % 31).collect();
        let mut ins = BTreeMap::new();
        ins.insert(0u16, input);
        let before = Interp::run(&app.dfg, &ins, 256).outputs[&0].clone();
        compute_pipelining(&mut g);
        let after = Interp::run(&g, &ins, 256).outputs[&0].clone();
        // Output equals the unpipelined stream delayed by the new latency.
        let out_node = g
            .nodes
            .iter()
            .position(|n| matches!(n.op, Op::Output { .. }))
            .unwrap();
        let shift =
            g.arrival_cycles()[out_node] - app.dfg.arrival_cycles()[out_node];
        let s = shift as usize;
        assert!(s > 0);
        // Skip the fill region of the unpipelined stream.
        let wd = crate::dfg::build::stencil_window_delay(32, 3) as usize;
        assert_eq!(&before[wd..256 - s], &after[wd + s..]);
    }

    #[test]
    fn regfile_transform_replaces_long_chains() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        let e = g.connect(i, o, 0);
        g.edge_mut(e).regs = 5;
        let n = regfile_transform(&mut g, 3);
        assert_eq!(n, 1);
        // A Delay{5} node now sits between input and output.
        let d = g
            .nodes
            .iter()
            .position(|n| matches!(n.op, Op::Delay { cycles: 5, pipelined: true }))
            .expect("delay node inserted");
        assert!(g.edges.iter().any(|e| e.src == i && e.dst == d as u32));
        assert!(g.edges.iter().any(|e| e.src == d as u32 && e.dst == o));
        assert_eq!(g.total_edge_regs(), 0);
        assert!(g.validate().is_empty(), "{:?}", g.validate());
    }

    #[test]
    fn regfile_transform_preserves_function() {
        let build = || {
            let mut g = Dfg::new();
            let i = g.add_node(Op::Input { lane: 0 }, "in");
            let m = g.add_node(Op::Alu { op: AluOp::Mul, const_b: Some(2) }, "m");
            let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
            g.connect(i, m, 0);
            let e = g.connect(m, o, 0);
            g.edge_mut(e).regs = 4;
            g
        };
        let input: Vec<i64> = (0..20).collect();
        let mut ins = BTreeMap::new();
        ins.insert(0u16, input);
        let g0 = build();
        let out0 = Interp::run(&g0, &ins, 20).outputs[&0].clone();
        let mut g1 = build();
        regfile_transform(&mut g1, 2);
        let out1 = Interp::run(&g1, &ins, 20).outputs[&0].clone();
        assert_eq!(out0, out1);
    }

    #[test]
    fn short_chains_untouched() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        let e = g.connect(i, o, 0);
        g.edge_mut(e).regs = 2;
        assert_eq!(regfile_transform(&mut g, 3), 0);
        assert_eq!(g.edge(e).regs, 2);
    }
}
