//! The Cascade pipelining passes and the end-to-end compile driver.
//!
//! Software techniques (paper §V):
//! * [`compute`] — compute pipelining (PE input registers) + the
//!   register-chain → register-file shift-register transform;
//! * [`bdm`] — branch delay matching, shared by every pass;
//! * [`broadcast`] — broadcast signal pipelining (tree transform);
//! * placement cost-function optimization lives in `pnr::place`
//!   (the `alpha` criticality exponent of Eq. 1);
//! * [`postpnr`] — post-place-and-route pipelining (switch-box register
//!   insertion on the critical path), including the sparse FIFO variant
//!   (§VII);
//! * [`unroll`] — low unrolling duplication;
//! * [`flush`] — the hardware flush-hardening optimization (§VI).
//!
//! [`compile`] runs the full Fig. 2 flow: map → schedule (round 1) →
//! DFG-level pipelining → place → route → register realization → post-PnR
//! pipelining → rescheduling (§V-F) → STA.

pub mod bdm;
pub mod compute;
pub mod broadcast;
pub mod postpnr;
pub mod flush;
pub mod unroll;

use crate::apps::App;
use crate::arch::canal::InterconnectGraph;
use crate::arch::delay::{DelayLib, DelayModelParams};
use crate::arch::params::ArchParams;
use crate::pnr::{place_and_route, PlaceParams, RouteParams};
use crate::schedule::{reschedule, schedule, Schedule};
use crate::timing::sta::{analyze, CritPath};

pub use broadcast::BroadcastParams;
pub use postpnr::{PostPnrParams, PostPnrReport};
pub use unroll::DupPlan;

/// Which pipelining techniques to apply (one flag per paper technique, so
/// the experiment harness can sweep them incrementally as in Fig. 7/10).
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// §V-A compute pipelining.
    pub compute: bool,
    /// §V-A register-chain transform threshold (None = off).
    pub regfile_threshold: Option<u32>,
    /// §V-B broadcast pipelining (None = off).
    pub broadcast: Option<BroadcastParams>,
    /// §V-C placement criticality exponent (1.0 = baseline placer).
    pub place_alpha: f64,
    /// Annealing-schedule scale for placement (1.0 = default effort;
    /// lowered by `--fast` runs and by the `explore` engine's CI mode).
    pub place_effort: f64,
    /// §V-D post-PnR pipelining (None = off).
    pub postpnr: Option<PostPnrParams>,
    /// §V-E low unrolling duplication (consumed by `compile_with_dup`).
    pub unroll_dup: bool,
    /// §VI hardened flush network.
    pub hardened_flush: bool,
    /// Op fusion ahead of mapping (`dfg::fuse`): collapse single-fanout
    /// ALU chains into compound PE ops. Changes the *mapping*, never the
    /// function — fused and unfused artifacts are semantically equivalent
    /// but not byte-identical, so this knob participates in
    /// `config_signature`/cache keys (see `docs/fusion.md`).
    pub fusion: bool,
}

impl PipelineConfig {
    /// No pipelining at all (the baseline compiler).
    pub fn none() -> Self {
        PipelineConfig {
            compute: false,
            regfile_threshold: None,
            broadcast: None,
            place_alpha: 1.0,
            place_effort: 1.0,
            postpnr: None,
            unroll_dup: false,
            hardened_flush: false,
            fusion: false,
        }
    }

    /// + compute pipelining.
    pub fn compute_only() -> Self {
        PipelineConfig { compute: true, regfile_threshold: Some(4), ..Self::none() }
    }

    /// + broadcast signal pipelining.
    pub fn with_broadcast() -> Self {
        PipelineConfig { broadcast: Some(BroadcastParams::default()), ..Self::compute_only() }
    }

    /// + placement cost-function optimization.
    pub fn with_placement() -> Self {
        PipelineConfig { place_alpha: 1.35, ..Self::with_broadcast() }
    }

    /// + post-PnR pipelining.
    pub fn with_postpnr() -> Self {
        PipelineConfig { postpnr: Some(PostPnrParams::default()), ..Self::with_placement() }
    }

    /// + low unrolling duplication: all software techniques (Fig. 7 final
    /// bar).
    pub fn all_software() -> Self {
        PipelineConfig { unroll_dup: true, ..Self::with_postpnr() }
    }

    /// All software techniques + the hardened flush network (§VI) — the
    /// configuration behind Table I "Pipelined".
    pub fn full() -> Self {
        PipelineConfig { hardened_flush: true, ..Self::all_software() }
    }

    /// Look up a pipelining level by its CLI / `explore`-axis name.
    pub fn by_name(name: &str) -> Option<PipelineConfig> {
        Some(match name {
            "none" => Self::none(),
            "compute" => Self::compute_only(),
            "broadcast" => Self::with_broadcast(),
            "placement" => Self::with_placement(),
            "postpnr" => Self::with_postpnr(),
            "all-software" => Self::all_software(),
            "full" => Self::full(),
            _ => return None,
        })
    }

    /// Every named level, in incremental order (CLI usage text and the
    /// `explore` grid validate against this).
    pub const LEVEL_NAMES: [&'static str; 7] =
        ["none", "compute", "broadcast", "placement", "postpnr", "all-software", "full"];

    /// The incremental ladder used by Fig. 7 (dense).
    pub fn ladder() -> Vec<(&'static str, PipelineConfig)> {
        vec![
            ("unpipelined", Self::none()),
            ("+compute", Self::compute_only()),
            ("+broadcast", Self::with_broadcast()),
            ("+placement", Self::with_placement()),
            ("+postpnr", Self::with_postpnr()),
            ("+duplication", Self::all_software()),
        ]
    }

    /// The incremental ladder used by Fig. 10 (sparse): compute pipelining
    /// is always on (FIFOs are inherent), broadcast/duplication had no
    /// effect, so the sweep is placement then post-PnR.
    pub fn sparse_ladder() -> Vec<(&'static str, PipelineConfig)> {
        vec![
            ("compute (default)", Self::compute_only()),
            ("+placement", PipelineConfig { place_alpha: 1.35, ..Self::compute_only() }),
            (
                "+postpnr",
                PipelineConfig {
                    place_alpha: 1.35,
                    postpnr: Some(PostPnrParams::default()),
                    ..Self::compute_only()
                },
            ),
        ]
    }
}

/// Shared compile context: architecture + delay-annotated interconnect
/// graph + timing model (expensive to build; reuse across compiles).
pub struct CompileCtx {
    pub arch: ArchParams,
    pub graph: InterconnectGraph,
    pub lib: DelayLib,
}

impl CompileCtx {
    pub fn new(arch: ArchParams) -> CompileCtx {
        let lib = DelayLib::generate(&arch, &DelayModelParams::default());
        let mut graph = InterconnectGraph::build(&arch);
        graph.annotate_delays(&lib);
        CompileCtx { arch, graph, lib }
    }

    /// The paper's 32x16 evaluation array.
    pub fn paper() -> CompileCtx {
        CompileCtx::new(ArchParams::paper())
    }
}

/// Compile error.
#[derive(Debug)]
pub enum CompileError {
    Map(crate::map::MapError),
    Route(crate::pnr::RouteError),
    Dup(String),
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::Map(e) => write!(f, "mapping: {e}"),
            CompileError::Route(e) => write!(f, "routing: {e}"),
            CompileError::Dup(s) => write!(f, "duplication: {s}"),
        }
    }
}

impl std::error::Error for CompileError {}

/// A fully compiled application.
pub struct Compiled {
    pub design: crate::pnr::RoutedDesign,
    pub sta: CritPath,
    pub schedule: Schedule,
    pub map_report: crate::map::MapReport,
    pub pes_pipelined: usize,
    pub bdm_regs: u64,
    pub bcast_buffers: usize,
    pub postpnr: Option<PostPnrReport>,
    pub dup: Option<DupPlan>,
    /// What the fusion pass did (None when `cfg.fusion` is off).
    pub fused: Option<crate::dfg::fuse::FuseReport>,
}

impl Compiled {
    pub fn fmax_mhz(&self) -> f64 {
        self.sta.fmax_mhz
    }

    pub fn runtime_ms(&self) -> f64 {
        crate::schedule::runtime_ms(&self.schedule, self.sta.fmax_mhz)
    }
}

/// Run the Fig. 2 compile flow on an application.
pub fn compile(
    app: &App,
    ctx: &CompileCtx,
    cfg: &PipelineConfig,
    seed: u64,
) -> Result<Compiled, CompileError> {
    compile_inner(app, ctx, cfg, seed, None)
}

fn compile_inner(
    app: &App,
    ctx: &CompileCtx,
    cfg: &PipelineConfig,
    seed: u64,
    region: Option<(crate::arch::params::TileCoord, (usize, usize))>,
) -> Result<Compiled, CompileError> {
    // Stage tracing: `obs::trace::mark` is a no-op unless the caller
    // installed a span sink (`obs::trace::with_spans`), so the untraced
    // flow pays one TLS load per stage and outputs never change.
    let arch = if cfg.hardened_flush { flush::harden(&ctx.arch) } else { ctx.arch.clone() };
    let mut dfg = app.dfg.clone();
    // Op fusion runs between DFG construction and mapping: the mapper,
    // placer and everything downstream see the compound nodes.
    let fused = cfg.fusion.then(|| crate::dfg::fuse::fuse_chains(&mut dfg));
    crate::obs::trace::mark("fuse");
    let map_report = crate::map::map_dfg(&mut dfg, &arch).map_err(CompileError::Map)?;
    crate::obs::trace::mark("map");

    let is_sparse = dfg.nodes.iter().any(|n| n.is_sparse());

    // DFG-level pipelining (dense only; sparse compute pipelining is
    // inherent in the FIFO interfaces).
    let mut pes_pipelined = 0;
    let mut bdm_regs = 0;
    let mut bcast_buffers = 0;
    if !is_sparse {
        if cfg.compute {
            let (pes, regs) = compute::compute_pipelining(&mut dfg);
            pes_pipelined = pes;
            bdm_regs += regs;
            if let Some(th) = cfg.regfile_threshold {
                compute::regfile_transform(&mut dfg, th);
            }
        }
        if let Some(bp) = &cfg.broadcast {
            bcast_buffers = broadcast::broadcast_pipelining(&mut dfg, bp);
        }
    }
    crate::obs::trace::mark("pipeline");

    // Round-1 schedule (paper §V-F: latencies as currently known).
    let sched1 = schedule(&dfg, &app.shape);
    crate::obs::trace::mark("schedule");

    // Place and route. The incremental switches select *how* the kernels
    // evaluate, never what they produce (see `docs/performance.md`), so
    // they are read from the process-wide config rather than `cfg`.
    let inc = crate::pnr::IncrementalCfg::current();
    let pp = PlaceParams {
        alpha: cfg.place_alpha,
        effort: cfg.place_effort,
        seed,
        region,
        incremental: inc.place,
        ..PlaceParams::default()
    };
    let rp = RouteParams { incremental: inc.route, ..RouteParams::default() };
    let mut design = place_and_route(&dfg, &arch, &ctx.graph, &ctx.lib, &pp, &rp)
        .map_err(CompileError::Route)?;
    design.realize_registers(&ctx.graph);
    crate::obs::trace::mark("realize");

    // Post-PnR pipelining.
    let postpnr_report =
        cfg.postpnr.as_ref().map(|p| postpnr::postpnr_pipelining(&mut design, &ctx.graph, p));
    crate::obs::trace::mark("postpnr");

    // Round-2 schedule with post-pipelining latencies (§V-F).
    let sched2 = reschedule(&design.dfg, &sched1);
    crate::obs::trace::mark("reschedule");

    let sta = analyze(&design, &ctx.graph);
    crate::obs::trace::mark("sta");
    Ok(Compiled {
        design,
        sta,
        schedule: sched2,
        map_report,
        pes_pipelined,
        bdm_regs,
        bcast_buffers,
        postpnr: postpnr_report,
        dup: None,
        fused,
    })
}

/// Compile with low unrolling duplication (§V-E): PnR a low-unroll variant
/// of the application in a narrow region and account the full-array
/// throughput. `builder(w, h, unroll)` must build the application at any
/// unrolling.
pub fn compile_with_dup(
    builder: &dyn Fn(u64, u64, u64) -> App,
    w: u64,
    h: u64,
    unroll: u64,
    ctx: &CompileCtx,
    cfg: &PipelineConfig,
    seed: u64,
) -> Result<Compiled, CompileError> {
    // Size the region from a single lane. Applications whose unrolled form
    // already fills the array have no duplication headroom (§V-E applies
    // to apps where the placer can solve a smaller problem); fall back to
    // the direct compile.
    let lane = builder(w / unroll, h, 1);
    let Some(plan) = unroll::plan_duplication(&lane.dfg, unroll, &ctx.arch) else {
        let app = builder(w, h, unroll);
        return compile_inner(&app, ctx, cfg, seed, None);
    };
    let k = plan.lanes_per_copy;
    let sub = builder(w * k / unroll, h, k);
    let region = Some(unroll::region_of(&plan, &ctx.arch));
    let mut compiled = compile_inner(&sub, ctx, cfg, seed, region)?;
    // Full-array schedule: the stamped copies together provide the
    // original unrolling over the original frame (same throughput
    // accounting as the direct compile — §V-E changes *how* the unroll is
    // placed, not how much work the frame is).
    let full = crate::schedule::WorkloadShape {
        frame_w: w,
        frame_h: h,
        unroll,
        time_mult: sub.shape.time_mult,
    };
    let s1 = schedule(&compiled.design.dfg, &full);
    compiled.schedule = s1;
    compiled.dup = Some(plan);
    Ok(compiled)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_improves_fmax_monotonically_ish() {
        // The headline behaviour: each Fig. 7 step should not hurt, and
        // the full ladder must be dramatically better than unpipelined.
        let ctx = CompileCtx::paper();
        let app = crate::apps::dense::gaussian(64, 64, 2);
        let mut last = 0.0;
        let mut first = 0.0;
        for (name, cfg) in PipelineConfig::ladder().into_iter().take(5) {
            // Hardened flush, as in the paper's Fig. 7 experiments.
            let cfg = PipelineConfig { hardened_flush: true, ..cfg };
            let c = compile(&app, &ctx, &cfg, 3).unwrap();
            if name == "unpipelined" {
                first = c.fmax_mhz();
            }
            last = c.fmax_mhz();
        }
        assert!(last > first * 2.5, "ladder {first} -> {last}");
    }

    #[test]
    fn full_config_close_to_paper_speedups() {
        let ctx = CompileCtx::paper();
        let app = crate::apps::dense::gaussian(64, 64, 2);
        let unpip = compile(&app, &ctx, &PipelineConfig::none(), 3).unwrap();
        let pip_cfg = PipelineConfig { hardened_flush: true, ..PipelineConfig::with_postpnr() };
        let pip = compile(&app, &ctx, &pip_cfg, 3).unwrap();
        let speedup = pip.fmax_mhz() / unpip.fmax_mhz();
        // Paper: 7-34x lower critical path on dense apps (full scale).
        // Small frames cap the gain; require at least 3x here.
        assert!(speedup > 3.0, "speedup {speedup}");
        // Schedule tracks the new latency.
        assert!(pip.schedule.fill_latency >= unpip.schedule.fill_latency);
    }

    #[test]
    fn compile_with_dup_produces_plan() {
        let ctx = CompileCtx::paper();
        let c = compile_with_dup(
            &|w, h, u| crate::apps::dense::gaussian(w, h, u),
            256,
            64,
            8,
            &ctx,
            &PipelineConfig::with_postpnr(),
            5,
        )
        .unwrap();
        let plan = c.dup.as_ref().unwrap();
        assert!(plan.copies * plan.lanes_per_copy as usize >= 8);
        // Full throughput accounted: steady cycles reflect total unroll.
        assert_eq!(
            c.schedule.shape.unroll,
            plan.lanes_per_copy * plan.copies as u64
        );
    }

    #[test]
    fn sparse_compile_all_ladder_steps() {
        let ctx = CompileCtx::paper();
        let app = crate::apps::sparse::vec_elemadd(1024, 0.2);
        let mut periods = Vec::new();
        for (_, cfg) in PipelineConfig::sparse_ladder() {
            let c = compile(&app, &ctx, &cfg, 11).unwrap();
            periods.push(c.sta.period_ps);
        }
        // Final (postpnr) must beat the first (compute-only).
        assert!(
            periods.last().unwrap() < periods.first().unwrap(),
            "{periods:?}"
        );
    }

    #[test]
    fn hardened_flush_config_removes_flush_net() {
        let ctx = CompileCtx::paper();
        let app = crate::apps::dense::gaussian(64, 64, 2);
        let c = compile(&app, &ctx, &PipelineConfig::full(), 3).unwrap();
        assert!(!c.design.has_routed_flush());
    }
}
