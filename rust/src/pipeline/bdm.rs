//! Branch delay matching (paper §III-B).
//!
//! When pipelining registers are added to an application DFG, every
//! synchronous join must still see all of its operands arrive on the same
//! cycle. BDM computes per-node arrival cycles (like STA, but in cycles
//! using node latencies + edge register counts) and adds balancing
//! registers to the earlier-arriving inputs.
//!
//! Exclusions:
//! * edges out of the flush source (a synchronous reset, not data);
//! * inputs of sparse (elastic) nodes — the ready/valid protocol absorbs
//!   latency mismatches, which is exactly why sparse pipelining can insert
//!   FIFOs without balancing (§VII).

use crate::dfg::ir::{Dfg, NodeId, Op};

/// Is this edge subject to balancing at its sink?
pub fn balanced_edge(g: &Dfg, ei: usize) -> bool {
    let e = &g.edges[ei];
    if matches!(g.node(e.src).op, Op::FlushSrc) {
        return false;
    }
    if e.fifos > 0 {
        return false;
    }
    g.node(e.dst).needs_balanced_inputs()
}

/// Run branch delay matching: add registers on earlier inputs of every
/// synchronous join so all *pipelining-added* arrivals match (algorithmic
/// latencies — line-buffer taps, ROM reads — are part of the application's
/// function and are never balanced away). Returns the number of registers
/// added. Idempotent: a second run adds zero.
pub fn branch_delay_match(g: &mut Dfg) -> u64 {
    let order = g.topo_order();
    let mut in_lists: Vec<Vec<usize>> = vec![Vec::new(); g.nodes.len()];
    for (ei, e) in g.edges.iter().enumerate() {
        in_lists[e.dst as usize].push(ei);
    }
    let mut arr = vec![0u64; g.nodes.len()];
    let mut added = 0u64;
    for &n in &order {
        // Added-latency arrival of each balanced input; target is the max.
        let mut target = 0u64;
        let mut have_balanced = false;
        for &ei in &in_lists[n as usize] {
            let e = &g.edges[ei];
            let a = arr[e.src as usize] + e.regs as u64;
            if balanced_edge(g, ei) {
                target = target.max(a);
                have_balanced = true;
            }
        }
        if have_balanced {
            for &ei in &in_lists[n as usize] {
                if !balanced_edge(g, ei) {
                    continue;
                }
                let e = &g.edges[ei];
                let a = arr[e.src as usize] + e.regs as u64;
                let deficit = target - a;
                if deficit > 0 {
                    g.edges[ei].regs += deficit as u32;
                    added += deficit;
                }
            }
        }
        // Node arrival = max over all *data* inputs (balanced now equal;
        // flush edges never contribute to data timing).
        let mut best = 0u64;
        for &ei in &in_lists[n as usize] {
            let e = &g.edges[ei];
            if matches!(g.node(e.src).op, Op::FlushSrc) {
                continue;
            }
            best = best.max(arr[e.src as usize] + e.regs as u64 + e.fifos as u64);
        }
        arr[n as usize] = best + g.node(n).added_latency() as u64;
    }
    added
}

/// Added-latency arrival cycles (the BDM quantity; cf.
/// `Dfg::arrival_cycles`, which includes algorithmic latencies and is the
/// scheduling quantity).
pub fn added_arrival_cycles(g: &Dfg) -> Vec<u64> {
    let mut in_lists: Vec<Vec<usize>> = vec![Vec::new(); g.nodes.len()];
    for (ei, e) in g.edges.iter().enumerate() {
        in_lists[e.dst as usize].push(ei);
    }
    let mut arr = vec![0u64; g.nodes.len()];
    for &n in &g.topo_order() {
        let mut best = 0u64;
        for &ei in &in_lists[n as usize] {
            let e = &g.edges[ei];
            if matches!(g.node(e.src).op, Op::FlushSrc) {
                continue;
            }
            best = best.max(arr[e.src as usize] + e.regs as u64 + e.fifos as u64);
        }
        arr[n as usize] = best + g.node(n).added_latency() as u64;
    }
    arr
}

/// Verify the BDM invariant: every balanced join sees equal added-latency
/// arrivals on all balanced inputs. Returns offending node ids.
pub fn check_balanced(g: &Dfg) -> Vec<NodeId> {
    let arr = added_arrival_cycles(g);
    let mut in_lists: Vec<Vec<usize>> = vec![Vec::new(); g.nodes.len()];
    for (ei, e) in g.edges.iter().enumerate() {
        in_lists[e.dst as usize].push(ei);
    }
    let mut bad = Vec::new();
    for n in 0..g.nodes.len() as NodeId {
        let arrivals: Vec<u64> = in_lists[n as usize]
            .iter()
            .filter(|&&ei| balanced_edge(g, ei))
            .map(|&ei| {
                let e = &g.edges[ei];
                arr[e.src as usize] + e.regs as u64
            })
            .collect();
        if arrivals.windows(2).any(|w| w[0] != w[1]) {
            bad.push(n);
        }
    }
    bad
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::interp::Interp;
    use crate::dfg::ir::AluOp;
    use std::collections::BTreeMap;

    /// in -> mul -> add ; in -> add : a classic reconvergence.
    fn diamond() -> Dfg {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let m = g.add_node(Op::Alu { op: AluOp::Mul, const_b: Some(3) }, "m");
        let a = g.add_node(Op::Alu { op: AluOp::Add, const_b: None }, "a");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, m, 0);
        g.connect(m, a, 0);
        g.connect(i, a, 1);
        g.connect(a, o, 0);
        g
    }

    #[test]
    fn balances_reconvergent_paths() {
        let mut g = diamond();
        // Pipeline the multiplier: its input registers add 1 cycle on the
        // long path.
        g.node_mut(1).input_regs = true;
        let added = branch_delay_match(&mut g);
        assert_eq!(added, 1, "short path needs one balancing register");
        assert!(check_balanced(&g).is_empty());
    }

    #[test]
    fn idempotent() {
        let mut g = diamond();
        g.node_mut(1).input_regs = true;
        branch_delay_match(&mut g);
        let again = branch_delay_match(&mut g);
        assert_eq!(again, 0);
    }

    #[test]
    fn functional_equivalence_after_bdm() {
        // Pipelined+BDM graph must produce the same stream, shifted.
        let input: Vec<i64> = (0..32).map(|x| (x * 11) % 23).collect();
        let mut ins = BTreeMap::new();
        ins.insert(0u16, input.clone());

        let g0 = diamond();
        let out0 = Interp::run(&g0, &ins, 32).outputs[&0].clone();

        let mut g1 = diamond();
        g1.node_mut(1).input_regs = true;
        branch_delay_match(&mut g1);
        let out1 = Interp::run(&g1, &ins, 32).outputs[&0].clone();

        // Latency shift = arrival at output in g1.
        let shift = g1.arrival_cycles()[3] as usize;
        assert_eq!(shift, 1);
        assert_eq!(&out0[..32 - shift], &out1[shift..]);
    }

    #[test]
    fn flush_edges_not_balanced() {
        let mut g = Dfg::new();
        let i = g.add_node(Op::Input { lane: 0 }, "in");
        let d = g.add_node(Op::Delay { cycles: 10, pipelined: false }, "lb");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(i, d, 0);
        g.connect(d, o, 0);
        let f = g.add_node(Op::FlushSrc, "flush");
        g.add_edge(f, d, 1, crate::arch::canal::Layer::B1);
        let added = branch_delay_match(&mut g);
        assert_eq!(added, 0, "flush must not trigger balancing");
    }

    #[test]
    fn sparse_joins_not_balanced() {
        let mut g = Dfg::new();
        let s1 =
            g.add_node(Op::Sparse(crate::dfg::ir::SparseOp::CrdScan { tensor: 0, mode: 0 }), "s1");
        let s2 =
            g.add_node(Op::Sparse(crate::dfg::ir::SparseOp::CrdScan { tensor: 1, mode: 0 }), "s2");
        let alu = g.add_node(Op::Sparse(crate::dfg::ir::SparseOp::SpAlu(AluOp::Add)), "a");
        let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
        g.connect(s1, alu, 0);
        g.connect(s2, alu, 1);
        g.connect(alu, o, 0);
        // Skew one input heavily.
        g.edges[0].regs = 5;
        let added = branch_delay_match(&mut g);
        assert_eq!(added, 0, "elastic inputs need no balancing");
    }

    #[test]
    fn deep_tree_balances_everywhere() {
        use crate::util::prop::forall;
        forall("random DAG balances", 30, |gen| {
            let mut g = Dfg::new();
            let i = g.add_node(Op::Input { lane: 0 }, "in");
            let mut pool = vec![i];
            let n = gen.usize(2, 24);
            for k in 0..n {
                let op = *gen.pick(&[AluOp::Add, AluOp::Mul, AluOp::Sub]);
                let a = *gen.pick(&pool);
                let b = *gen.pick(&pool);
                let node = g.add_node(Op::Alu { op, const_b: None }, format!("n{k}"));
                g.connect(a, node, 0);
                if a == b {
                    let p = g.add_node(Op::Alu { op: AluOp::Pass, const_b: None }, format!("p{k}"));
                    g.connect(a, p, 0);
                    g.connect(p, node, 1);
                } else {
                    g.connect(b, node, 1);
                }
                // Randomly pipeline some nodes.
                if gen.bool(0.5) {
                    g.node_mut(node).input_regs = true;
                }
                pool.push(node);
            }
            let o = g.add_node(Op::Output { lane: 0, decimate: 1 }, "o");
            g.connect(*pool.last().unwrap(), o, 0);
            branch_delay_match(&mut g);
            assert!(check_balanced(&g).is_empty(), "unbalanced after BDM");
        });
    }
}
