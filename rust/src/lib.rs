//! # Cascade — an application pipelining toolkit for CGRAs
//!
//! Reproduction of *"Cascade: An Application Pipelining Toolkit for
//! Coarse-Grained Reconfigurable Arrays"* (Melchert, Mei, Koul, Liu,
//! Horowitz, Raina — Stanford, 2022).
//!
//! The crate implements the full stack the paper describes:
//!
//! * [`arch`] — the target CGRA architecture model: a 32x16 tile array with
//!   PE / MEM / IO tiles, a Canal-style configurable interconnect graph with
//!   single-cycle multi-hop routing and configurable pipelining registers in
//!   every switch box, a per-component timing model, and bitstream encoding.
//! * [`dfg`] — the application dataflow-graph IR shared by every compiler
//!   stage, with a halide-lite frontend used by the benchmark applications.
//! * [`map`] — compute mapping from primitive-op DAGs onto PE DAGs.
//! * [`schedule`] — static cycle-accurate scheduling of affine loop nests
//!   onto memory-tile address generators, plus post-pipelining rescheduling.
//! * [`pnr`] — simulated-annealing placement (with the paper's Eq. 1 cost,
//!   including the `alpha` criticality exponent) and a negotiated-congestion
//!   (PathFinder-style) router over the interconnect graph.
//! * [`timing`] — static timing analysis of mapped applications and an
//!   SDF-annotated gate-level-simulation surrogate used to validate the STA
//!   model (paper Fig. 6).
//! * [`pipeline`] — the Cascade passes: compute pipelining with branch delay
//!   matching, register-chain to shift-register transform, broadcast signal
//!   pipelining, post-PnR pipelining, low unrolling duplication, flush
//!   hardening (hardware technique), and the sparse FIFO-insertion variants.
//! * [`sparse`] — the ready-valid streaming substrate and the four sparse
//!   workloads (vector add, matrix elementwise mul, MTTKRP, TTV).
//! * [`sim`] — cycle-level functional simulation of the configured fabric
//!   (dense and sparse) and the activity-based power / EDP model.
//! * [`runtime`] — the PJRT golden-model runtime: loads AOT-compiled JAX /
//!   Pallas HLO artifacts and executes them to check functional equivalence
//!   of the CGRA simulation results (gated behind the `golden-pjrt` cargo
//!   feature; a stub with the same API reports it unavailable in offline
//!   builds).
//! * [`apps`] — the benchmark applications from the paper's evaluation.
//! * [`experiments`] — regenerators for every table and figure in the paper.
//! * [`explore`] — the design-space exploration engine: a parallel
//!   work-queue sweep over compiler axes (app × pipelining level ×
//!   placement alpha × PnR seed × post-PnR iteration budget) and
//!   architecture axes (routing tracks × regfile words × FIFO depth) with
//!   content-hash artifact caching, adaptive successive halving
//!   (`--search halving`), streamed partial results, Capstone-style power
//!   capping, and Pareto-frontier / knee-point reporting over
//!   (critical-path delay, EDP, pipelining registers). `--shard K/N`
//!   distributes a sweep across processes or machines via self-describing
//!   shard manifests that `cascade explore-merge` validates and reassembles
//!   into the identical single-process report. Drives `cascade explore`;
//!   `cascade exp summary` reuses its persistent cache.
//! * [`serve`] — the `cascade serve` daemon: a std-only TCP server
//!   (newline-delimited JSON protocol, bounded worker pool, per-connection
//!   request pipelining with back-pressure) that serves `compile` /
//!   `encode` / `stat` requests from one long-lived warm session over the
//!   explore caches, with in-flight deduplication, periodic pinned-aware
//!   GC, shared-secret auth (`--auth-token`, required off loopback), and
//!   graceful drain-on-shutdown. `--route` runs the same binary as a
//!   *front* that hash-routes requests to N backends by effective cache
//!   key (the `--shard` partition), payload-transparently. The keep-alive
//!   [`serve::Client`] API drives any of them (`cascade client`), and
//!   [`serve::loadgen`] measures one (`cascade loadgen`,
//!   `BENCH_serve.json`).
//! * [`obs`] — zero-dependency observability: a process-wide metrics
//!   registry (atomic counters / gauges / log₂-bucketed latency histograms
//!   with exact p50/p99/p999 readout) rendering a byte-deterministic
//!   Prometheus-style text exposition, a thread-local per-stage span
//!   tracer threaded through the compile pipeline, and a size-bounded
//!   JSONL request/event log. Feeds the daemon's `metrics` wire op and
//!   `cascade explore --profile`.
//! * [`benchsuite`] — the seed benchmark kernels (`cargo bench` targets
//!   call into these) re-exposed as library calls so `cascade bench
//!   --json` can record a perf trajectory point without cargo.
//! * [`util`] — in-house substrates: deterministic PRNG, JSON writer,
//!   mini property-testing framework, statistics helpers, micro-bench timer.

pub mod util;
pub mod obs;
pub mod benchsuite;
pub mod arch;
pub mod dfg;
pub mod map;
pub mod schedule;
pub mod pnr;
pub mod timing;
pub mod pipeline;
pub mod sparse;
pub mod sim;
pub mod runtime;
pub mod apps;
pub mod experiments;
pub mod explore;
pub mod serve;
