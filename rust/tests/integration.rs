//! Cross-module integration tests: full compiles at every pipelining level
//! with functional verification against the reference interpreter, plus
//! bitstream round-trips and schedule consistency.

use std::collections::BTreeMap;

use cascade::dfg::interp::Interp;
use cascade::pipeline::{compile, CompileCtx, PipelineConfig};
use cascade::sim::dense::FabricSim;

fn ctx() -> CompileCtx {
    CompileCtx::paper()
}

/// The full-stack functional law: for any dense app and any pipelining
/// level, the fabric simulation of the compiled design equals the
/// unpipelined reference stream delayed by the added-latency arrival at
/// each output.
fn assert_function_preserved(app: cascade::apps::App, cfg: &PipelineConfig, seed: u64) {
    let ctx = ctx();
    let c = compile(&app, &ctx, cfg, seed).unwrap_or_else(|e| panic!("{}: {e}", app.name));
    c.design.registers_consistent().unwrap();
    assert!(cascade::pipeline::bdm::check_balanced(&c.design.dfg).is_empty());

    let n = 1024u64;
    let mut ins = BTreeMap::new();
    for (i, node) in app.dfg.nodes.iter().enumerate() {
        let _ = i;
        if let cascade::dfg::ir::Op::Input { lane } = node.op {
            ins.insert(
                lane,
                (0..n as i64).map(|x| (x * 7 + lane as i64 * 3 + 5) % 29).collect::<Vec<i64>>(),
            );
        }
    }
    let base = Interp::run(&app.dfg, &ins, n);
    let fab = FabricSim::run(&c.design, &ins, n);
    let added = cascade::pipeline::bdm::added_arrival_cycles(&c.design.dfg);
    for (i, node) in c.design.dfg.nodes.iter().enumerate() {
        if let cascade::dfg::ir::Op::Output { lane, .. } = node.op {
            let s = added[i] as usize;
            let b = &base.outputs[&lane];
            let f = &fab.outputs[&lane];
            // Skip apps with accumulators from the pure-shift law (their
            // outputs are schedule-sampled; covered by the e2e example).
            if app.dfg.nodes.iter().any(|nd| matches!(nd.op, cascade::dfg::ir::Op::Accum { .. })) {
                continue;
            }
            assert_eq!(
                &b[..n as usize - s],
                &f[s..],
                "{} lane {lane}: function not preserved (shift {s})",
                app.name
            );
        }
    }
}

#[test]
fn gaussian_all_levels_preserve_function() {
    for (name, cfg) in PipelineConfig::ladder() {
        if name == "+duplication" {
            continue; // region compile changes the app's shape; covered below
        }
        assert_function_preserved(cascade::apps::dense::gaussian(64, 16, 1), &cfg, 3);
    }
}

#[test]
fn camera_full_preserves_function() {
    assert_function_preserved(
        cascade::apps::dense::camera(64, 16, 1),
        &PipelineConfig::with_postpnr(),
        5,
    );
}

#[test]
fn unsharp_full_preserves_function() {
    assert_function_preserved(
        cascade::apps::dense::unsharp(64, 16, 1),
        &PipelineConfig::with_postpnr(),
        7,
    );
}

#[test]
fn harris_full_preserves_function() {
    assert_function_preserved(
        cascade::apps::dense::harris(64, 16, 1),
        &PipelineConfig::with_postpnr(),
        9,
    );
}

#[test]
fn multilane_app_preserves_function() {
    assert_function_preserved(
        cascade::apps::dense::gaussian(128, 16, 2),
        &PipelineConfig::with_postpnr(),
        11,
    );
}

#[test]
fn hardened_flush_preserves_function() {
    assert_function_preserved(
        cascade::apps::dense::gaussian(64, 16, 1),
        &PipelineConfig::full(),
        13,
    );
}

#[test]
fn duplication_region_design_is_functional() {
    let ctx = ctx();
    let c = cascade::pipeline::compile_with_dup(
        &|w, h, u| cascade::apps::dense::gaussian(w, h, u),
        256,
        16,
        8,
        &ctx,
        &PipelineConfig::with_postpnr(),
        5,
    )
    .unwrap();
    // The region design simulates correctly against its own reference.
    let sub_lanes: Vec<u16> = c
        .design
        .dfg
        .nodes
        .iter()
        .filter_map(|n| match n.op {
            cascade::dfg::ir::Op::Input { lane } => Some(lane),
            _ => None,
        })
        .collect();
    assert!(!sub_lanes.is_empty());
    let mut ins = BTreeMap::new();
    for lane in &sub_lanes {
        ins.insert(*lane, (0..512).map(|x| (x * 5 + 1) % 23).collect::<Vec<i64>>());
    }
    let logical = Interp::run(&c.design.dfg, &ins, 512);
    let fabric = FabricSim::run(&c.design, &ins, 512);
    for (lane, v) in &logical.outputs {
        assert_eq!(v, &fabric.outputs[lane]);
    }
    // And the bitstream can be stamped across the array.
    let bs0 = cascade::sim::encode::encode(&c.design, &c.schedule, &ctx.graph);
    let cs = cascade::arch::bitstream::ConfigSpace::new(&c.design.arch);
    let mut bs = bs0.clone();
    let plan = c.dup.clone().unwrap();
    let copies = cascade::pipeline::unroll::stamp_bitstream(&mut bs, &plan, &c.design.arch, &cs);
    assert!(copies >= 2);
}

#[test]
fn sparse_compile_simulate_all_apps_all_levels() {
    let ctx = ctx();
    for app in cascade::apps::paper_sparse_suite() {
        let data = cascade::apps::sparse::data_for(app.name, 42);
        let expect = cascade::sparse::golden::golden(app.name, &data);
        for (lname, cfg) in PipelineConfig::sparse_ladder() {
            let c = compile(&app, &ctx, &cfg, 11).unwrap_or_else(|e| panic!("{}: {e}", app.name));
            let run = cascade::sparse::sim::simulate_app(app.name, &c.design.dfg, &data);
            assert_eq!(run.outputs, expect, "{} @ {lname}", app.name);
        }
    }
}

#[test]
fn schedule_round_trip_consistency() {
    let ctx = ctx();
    let app = cascade::apps::dense::gaussian(6400, 4800, 16);
    let un = compile(&app, &ctx, &PipelineConfig::none(), 3).unwrap();
    let pi = compile(&app, &ctx, &PipelineConfig::with_postpnr(), 3).unwrap();
    // Pipelining never changes steady-state throughput, only fill latency.
    assert_eq!(
        un.schedule.total_cycles - un.schedule.fill_latency,
        pi.schedule.total_cycles - pi.schedule.fill_latency
    );
    assert!(pi.schedule.fill_latency >= un.schedule.fill_latency);
}

#[test]
fn bitstream_roundtrip_every_dense_app() {
    let ctx = ctx();
    for app in cascade::apps::small_dense_suite() {
        let c = compile(&app, &ctx, &PipelineConfig::with_postpnr(), 3).unwrap();
        let bs = cascade::sim::encode::encode(&c.design, &c.schedule, &ctx.graph);
        let problems = cascade::sim::encode::verify_roundtrip(&c.design, &bs, &ctx.graph);
        assert!(problems.is_empty(), "{}: {problems:?}", app.name);
    }
}

#[test]
fn paper_headline_shape_dense() {
    // The core claim at test scale: full pipelining wins by a large factor
    // on critical path, and EDP falls.
    let ctx = ctx();
    let app = cascade::apps::dense::gaussian(6400, 4800, 16);
    let un = compile(&app, &ctx, &PipelineConfig::none(), 3).unwrap();
    let pi = compile(&app, &ctx, &PipelineConfig::full(), 3).unwrap();
    let cp_ratio = un.sta.period_ps / pi.sta.period_ps;
    assert!(cp_ratio > 3.0, "critical path ratio {cp_ratio}");
    let m = cascade::sim::power::EnergyModel::default();
    let e0 = cascade::sim::power::estimate(&un.design, un.fmax_mhz(), &m).edp(un.runtime_ms());
    let e1 = cascade::sim::power::estimate(&pi.design, pi.fmax_mhz(), &m).edp(pi.runtime_ms());
    assert!(e0 / e1 > 3.0, "EDP ratio {}", e0 / e1);
}

#[test]
fn property_router_legality_random_placements() {
    // Property test over seeds: routing never overuses a node and always
    // connects the right terminals.
    use cascade::arch::params::ArchParams;
    use cascade::pnr::{build_nets, place, route, PlaceParams, RouteParams};
    let ctx = ctx();
    let arch = ArchParams::paper();
    let app = cascade::apps::dense::unsharp(64, 16, 1);
    let nets = build_nets(&app.dfg, &arch);
    for seed in [1u64, 17, 99, 1234] {
        let placement = place(&app.dfg, &nets, &arch, &PlaceParams::baseline(seed));
        let routes =
            route(&app.dfg, &nets, &placement, &arch, &ctx.graph, &RouteParams::default())
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let mut used = std::collections::HashMap::new();
        for r in &routes {
            for n in r.nodes() {
                *used.entry(n).or_insert(0u32) += 1;
            }
        }
        assert!(used.values().all(|&c| c <= 1), "seed {seed}: overuse");
    }
}
