//! End-to-end acceptance for the routed serve topology (ISSUE 7):
//!
//! * **Transparency** — a 1-front/2-backend topology answers `compile`
//!   and `encode` with responses *byte-identical* to a direct
//!   single-daemon run once the three timing members (`queue_ms`,
//!   `exec_ms`, `ms` — the only fields a front legitimately re-measures)
//!   are normalized; bitstream and key fields are compared raw;
//! * **Partition** — each effective key lands on exactly the backend
//!   `owner_of` names, observed through per-backend `fresh_compiles`;
//! * **Auth** — a token-gated topology rejects missing and wrong
//!   secrets with `unauthorized` and accepts the right one;
//! * **Failure policy** — a key owned by a dead backend earns
//!   `backend_down` after the front's retry, while keys owned by live
//!   backends keep working;
//! * **Per-request queueing** — each request in a pipelined burst is
//!   charged its *own* dequeue-to-dispatch wait (the second of two
//!   back-to-back compiles must report a positive `queue_ms`).
//!
//! All tests skip (with a note) when the environment has no loopback
//! networking, mirroring `tests/serve.rs`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use cascade::arch::params::ArchParams;
use cascade::explore::runner::effective_key;
use cascade::explore::shard::owner_of;
use cascade::pipeline::CompileCtx;
use cascade::serve::proto::{PointQuery, Request};
use cascade::serve::{Client, ClientOpts, ServeConfig, Server};
use cascade::util::json::Json;

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cascade-route-e2e-{tag}-{}", std::process::id()))
}

fn config(dir: &std::path::Path) -> ServeConfig {
    let mut cfg = ServeConfig::new("127.0.0.1:0");
    cfg.workers = 2;
    cfg.queue_cap = 8;
    cfg.cache_dir = dir.to_path_buf();
    cfg
}

fn bind_or_skip(cfg: ServeConfig) -> Option<Server> {
    match Server::bind(cfg) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping route e2e: {e}");
            None
        }
    }
}

fn point(seed: u64) -> PointQuery {
    PointQuery {
        app: "gaussian".into(),
        level: Some("compute".into()),
        seed: Some(seed),
        fast: true,
        tiny: true,
        ..PointQuery::default()
    }
}

fn opts() -> ClientOpts {
    ClientOpts { timeout: Duration::from_secs(300), ..ClientOpts::default() }
}

fn auth_opts(token: &str) -> ClientOpts {
    ClientOpts { auth: Some(token.to_string()), ..opts() }
}

/// Find one seed per backend slot (0-based) under the 2-way partition,
/// scanning deterministically from seed 1.
fn seed_owned_by(slot: usize, n: usize) -> u64 {
    let arch = ArchParams::paper();
    for seed in 1..=64 {
        let (spec, p) = point(seed).resolve().unwrap();
        if owner_of(effective_key(&spec, &arch, &p), n) - 1 == slot {
            return seed;
        }
    }
    unreachable!("no seed in 1..=64 maps to backend {slot} of {n}");
}

/// Normalize the only members a routed front re-measures, then render
/// canonically: everything else must be byte-identical. A routed front
/// additionally preserves the backend's own timing split under a nested
/// `backend` member (ISSUE 10) — a documented timing member, dropped
/// here like the top-level three.
fn strip_timing(mut j: Json) -> String {
    for k in ["queue_ms", "exec_ms", "ms"] {
        j.set(k, 0);
    }
    let _ = j.remove("backend");
    j.to_string_compact()
}

#[test]
fn routed_front_is_byte_transparent_and_splits_by_partition() {
    let ctx = CompileCtx::paper();
    let dirs: Vec<_> = ["direct", "b1", "b2"].iter().map(|t| tmp(&format!("transp-{t}"))).collect();
    for d in &dirs {
        let _ = std::fs::remove_dir_all(d);
    }

    let Some(direct) = bind_or_skip(config(&dirs[0])) else { return };
    let Some(b1) = bind_or_skip(config(&dirs[1])) else { return };
    let Some(b2) = bind_or_skip(config(&dirs[2])) else { return };
    let direct_addr = direct.addr().to_string();
    let backend_addrs = vec![b1.addr().to_string(), b2.addr().to_string()];

    // One seed per backend, so the split is exercised in both directions.
    let seeds = [seed_owned_by(0, 2), seed_owned_by(1, 2)];

    std::thread::scope(|s| {
        s.spawn(|| direct.run(&ctx).unwrap());
        s.spawn(|| b1.run(&ctx).unwrap());
        s.spawn(|| b2.run(&ctx).unwrap());

        // The front handshakes its backends at construction, so it
        // binds only after they are accepting.
        let mut fcfg = config(&tmp("transp-front"));
        fcfg.route = backend_addrs.clone();
        let front = Server::bind(fcfg).expect("front binds");
        let front_addr = front.addr().to_string();
        s.spawn(|| front.run(&ctx).unwrap());

        let mut cd = Client::connect(direct_addr.as_str(), opts()).unwrap();
        let mut cf = Client::connect(front_addr.as_str(), opts()).unwrap();

        for &seed in &seeds {
            let q = point(seed);
            // Same conversation against both systems: compile, then
            // encode the warmed point.
            let rd = cd.compile(&q).unwrap();
            let rf = cf.compile(&q).unwrap();
            assert_eq!(rd.get("ok").and_then(Json::as_bool), Some(true), "{rd:?}");
            assert_eq!(rf.get("ok").and_then(Json::as_bool), Some(true), "{rf:?}");
            // The front re-measures the top-level timing but must not
            // discard the backend's split: it lives on under `backend`.
            let b = rf.get("backend").expect("routed response nests backend timing");
            for k in ["queue_ms", "exec_ms", "ms"] {
                assert!(
                    b.get(k).and_then(Json::as_f64).is_some(),
                    "backend.{k} missing from routed response: {rf:?}"
                );
            }
            assert!(
                rd.get("backend").is_none(),
                "direct response must not nest backend timing: {rd:?}"
            );
            assert_eq!(
                strip_timing(rd),
                strip_timing(rf),
                "routed compile response differs from direct (seed {seed})"
            );

            let ed = cd.encode_point(&q).unwrap();
            let ef = cf.encode_point(&q).unwrap();
            assert_eq!(
                ed.get("bitstream").and_then(Json::as_str),
                ef.get("bitstream").and_then(Json::as_str),
                "routed bitstream differs from direct (seed {seed})"
            );
            assert_eq!(ed.get("key"), ef.get("key"));
            assert_eq!(
                strip_timing(ed),
                strip_timing(ef),
                "routed encode response differs from direct (seed {seed})"
            );
        }

        // The front's stat fan-out proves the partition: each backend
        // compiled exactly its own key, and the totals line up.
        let stat = cf.stat().unwrap();
        assert_eq!(stat.get("role").and_then(Json::as_str), Some("front"));
        let backends = stat.get("backends").and_then(Json::as_arr).expect("backends array");
        assert_eq!(backends.len(), 2);
        for b in backends {
            let fresh = b
                .get("stat")
                .and_then(|s| s.get("server"))
                .and_then(|s| s.get("fresh_compiles"))
                .and_then(Json::as_u64);
            assert_eq!(fresh, Some(1), "each backend owns exactly one of the two keys: {b:?}");
        }
        let totals = stat.get("totals").expect("totals section");
        assert_eq!(totals.get("fresh_compiles").and_then(Json::as_u64), Some(2));

        // Fan-out ping answers with the topology.
        let ping = cf.ping().unwrap();
        assert_eq!(ping.get("role").and_then(Json::as_str), Some("front"));
        assert_eq!(ping.get("backends").and_then(Json::as_arr).map(<[Json]>::len), Some(2));

        // Shut down the front (drains only the front), then the
        // backends and the direct daemon.
        cf.shutdown().unwrap();
        for addr in &backend_addrs {
            let mut c = Client::connect(addr.as_str(), opts()).unwrap();
            c.shutdown().unwrap();
        }
        cd.shutdown().unwrap();
    });

    for d in &dirs {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn auth_gates_the_routed_topology() {
    let ctx = CompileCtx::paper();
    let dir = tmp("auth-backend");
    let _ = std::fs::remove_dir_all(&dir);

    let mut bcfg = config(&dir);
    bcfg.auth_token = Some("open-sesame".into());
    let Some(backend) = bind_or_skip(bcfg) else { return };
    let backend_addr = backend.addr().to_string();

    std::thread::scope(|s| {
        s.spawn(|| backend.run(&ctx).unwrap());

        let mut fcfg = config(&tmp("auth-front"));
        fcfg.auth_token = Some("open-sesame".into());
        fcfg.route = vec![backend_addr.clone()];
        let front = Server::bind(fcfg).expect("front binds");
        let front_addr = front.addr().to_string();
        s.spawn(|| front.run(&ctx).unwrap());

        // No token: rejected before the op is even interpreted.
        let mut anon = Client::connect(front_addr.as_str(), opts()).unwrap();
        let r = anon.ping().unwrap();
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "{r:?}");
        assert_eq!(r.get("code").and_then(Json::as_str), Some("unauthorized"));

        // Wrong token: same rejection, same connection stays usable.
        let mut wrong = Client::connect(front_addr.as_str(), auth_opts("guess")).unwrap();
        let r = wrong.ping().unwrap();
        assert_eq!(r.get("code").and_then(Json::as_str), Some("unauthorized"), "{r:?}");

        // Right token: the whole pipeline works end to end, front
        // through backend.
        let mut ok = Client::connect(front_addr.as_str(), auth_opts("open-sesame")).unwrap();
        let r = ok.compile(&point(1)).unwrap();
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r:?}");
        assert!(r.get("key").and_then(Json::as_str).is_some());

        ok.shutdown().unwrap();
        let mut b = Client::connect(backend_addr.as_str(), auth_opts("open-sesame")).unwrap();
        b.shutdown().unwrap();
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dead_backend_earns_backend_down_while_live_keys_keep_working() {
    let ctx = CompileCtx::paper();
    let dir = tmp("down-live");
    let _ = std::fs::remove_dir_all(&dir);

    let Some(live) = bind_or_skip(config(&dir)) else { return };
    let live_addr = live.addr().to_string();

    std::thread::scope(|s| {
        s.spawn(|| live.run(&ctx).unwrap());

        // Slot 0 is the live backend; slot 1 is a dead address (the
        // front warns at construction but still binds).
        let mut fcfg = config(&tmp("down-front"));
        fcfg.route = vec![live_addr.clone(), "127.0.0.1:1".into()];
        let front = Server::bind(fcfg).expect("front binds despite a dead backend");
        let front_addr = front.addr().to_string();
        s.spawn(|| front.run(&ctx).unwrap());

        let mut c = Client::connect(front_addr.as_str(), opts()).unwrap();

        // A key owned by the dead backend: structured failure, not a
        // hang and not a dropped connection.
        let r = c.compile(&point(seed_owned_by(1, 2))).unwrap();
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false), "{r:?}");
        assert_eq!(r.get("code").and_then(Json::as_str), Some("backend_down"));

        // A key owned by the live backend still compiles — on the same
        // front connection.
        let r = c.compile(&point(seed_owned_by(0, 2))).unwrap();
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r:?}");

        c.shutdown().unwrap();
        let mut b = Client::connect(live_addr.as_str(), opts()).unwrap();
        b.shutdown().unwrap();
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pipelined_requests_each_pay_their_own_queue_wait() {
    let ctx = CompileCtx::paper();
    let dir = tmp("queue");
    let _ = std::fs::remove_dir_all(&dir);
    let Some(server) = bind_or_skip(config(&dir)) else { return };
    let addr = server.addr().to_string();

    std::thread::scope(|s| {
        s.spawn(|| server.run(&ctx).unwrap());

        // Raw socket: write two compiles back to back *before* reading,
        // so the second demonstrably waits behind the first's execution.
        let stream = TcpStream::connect(&addr).unwrap();
        let line = Request::Compile(point(1)).to_json().to_string_compact();
        let mut w = stream.try_clone().unwrap();
        w.write_all(format!("{line}\n{line}\n").as_bytes()).unwrap();
        let mut reader = BufReader::new(stream);
        let mut l1 = String::new();
        reader.read_line(&mut l1).unwrap();
        let mut l2 = String::new();
        reader.read_line(&mut l2).unwrap();
        let r1 = Json::parse(l1.trim()).unwrap();
        let r2 = Json::parse(l2.trim()).unwrap();
        assert_eq!(r1.get("ok").and_then(Json::as_bool), Some(true), "{r1:?}");
        assert_eq!(r2.get("ok").and_then(Json::as_bool), Some(true), "{r2:?}");

        // The bug this guards against: charging the connection's first
        // request for the accept wait and every later request nothing.
        // The second request sat queued for the whole first compile, so
        // its own queue_ms must say so.
        let q2 = r2.get("queue_ms").and_then(Json::as_f64).expect("queue_ms");
        let e1 = r1.get("exec_ms").and_then(Json::as_f64).expect("exec_ms");
        assert!(q2 > 0.0, "pipelined request reported no queue wait: {r2:?}");
        assert!(
            q2 >= e1 * 0.5,
            "second request's queue wait ({q2} ms) should cover most of the first's \
             execution ({e1} ms)"
        );

        w.write_all(format!("{}\n", Request::Shutdown.to_json().to_string_compact()).as_bytes())
            .unwrap();
        let mut bye = String::new();
        reader.read_line(&mut bye).unwrap();
        assert_eq!(
            Json::parse(bye.trim()).unwrap().get("ok").and_then(Json::as_bool),
            Some(true)
        );
    });
    let _ = std::fs::remove_dir_all(&dir);
}
