//! End-to-end acceptance for compiled-artifact persistence (ISSUE 4):
//!
//! * an `explore` run followed by `cascade encode --from-cache` on a knee
//!   point produces a bitstream **byte-identical** to a fresh compile of
//!   that point, with **zero recompiles** on the cached path;
//! * `cache gc` under a cap smaller than the store evicts only unpinned
//!   entries, and the report regenerated afterwards is unchanged;
//! * a resumed run rehydrates warm artifacts instead of recompiling.

use cascade::explore::artifact::CacheCap;
use cascade::explore::{report, runner, DiskCache, ExploreSpec, Scale};
use cascade::pipeline::CompileCtx;
use cascade::sim::encode::encode_compiled;

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cascade-art-e2e-{tag}-{}", std::process::id()))
}

fn tiny_spec() -> ExploreSpec {
    ExploreSpec::default()
        .with_apps(["gaussian"])
        .with_levels(["none", "compute"])
        .with_seeds([1])
        .with_fast(true)
        .with_scale(Scale::Tiny)
}

#[test]
fn encode_from_cache_is_byte_identical_with_zero_recompiles() {
    let dir = tmp("encode");
    let _ = std::fs::remove_dir_all(&dir);
    let ctx = CompileCtx::paper();
    let spec = tiny_spec();

    // The sweep persists one artifact per distinct effective config.
    let dc = DiskCache::at(&dir);
    let out = cascade::explore::run(&spec, &ctx, 2, Some(&dc));
    assert!(out.results.iter().all(|r| r.metrics.is_ok()));
    assert_eq!(dc.artifacts().stores(), out.stats.misses);

    // Pick the knee point, exactly as the report does.
    let analyses = report::analyze(&spec, &out.results);
    let knee_id = analyses[0].knee.expect("tiny sweep has a knee point");
    let knee = out.results.iter().find(|r| r.point.id == knee_id).unwrap();
    let key = runner::effective_key(&spec, &ctx.arch, &knee.point);

    // The `--from-cache` path: rehydrate (fingerprint-verified against the
    // metrics record) and encode. No compiler entry point is touched.
    let dc2 = DiskCache::at(&dir);
    let expect_fp = dc2.load(key).expect("metrics record present").artifact_fp;
    let cached = dc2.artifacts().load(key, Some(expect_fp)).expect("artifact present");
    assert_eq!(dc2.artifacts().hits(), 1);
    assert_eq!(dc2.artifacts().rejected(), 0, "zero recompiles: nothing was rejected");
    let bs_cached = encode_compiled(&cached);

    // A fresh compile of the same point, through the same dispatch the
    // sweep used.
    let (cfg, arch, _) = runner::effective_point(&spec, &ctx.arch, &knee.point);
    let fresh_ctx = CompileCtx::new(arch);
    let fresh = runner::compile_effective(&spec, &knee.point, &cfg, &fresh_ctx).unwrap();
    let bs_fresh = encode_compiled(&fresh);

    assert_eq!(
        bs_cached.to_text(),
        bs_fresh.to_text(),
        "cached-artifact bitstream must be byte-identical to a fresh compile's"
    );
    assert!(!bs_cached.is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gc_under_cap_keeps_pinned_knee_and_report_is_unchanged() {
    let dir = tmp("gc");
    let _ = std::fs::remove_dir_all(&dir);
    let ctx = CompileCtx::paper();
    let spec = tiny_spec();

    let dc = DiskCache::at(&dir);
    let out = cascade::explore::run(&spec, &ctx, 2, Some(&dc));
    let analyses = report::analyze(&spec, &out.results);
    let (md1, json1, _) = report::render_report(&spec, &out.results, None);

    // Pin the frontier/knee survivors the way `run_cli` does, then GC
    // under a cap smaller than the store.
    let knee_id = analyses[0].knee.unwrap();
    let knee = out.results.iter().find(|r| r.point.id == knee_id).unwrap();
    let knee_key = runner::effective_key(&spec, &ctx.arch, &knee.point);
    dc.artifacts().pin([knee_key]);
    let entries = dc.artifacts().keys().len();
    assert!(entries > 1, "need something evictable");
    let r = dc.artifacts().gc(&CacheCap::entries(1));
    assert_eq!(r.evicted, entries - 1, "everything unpinned under a 1-entry cap goes");
    assert!(dc.artifacts().contains(knee_key), "the pinned knee artifact survives");

    // The report's source of truth is the metrics records, which GC never
    // touches: a re-run over the same cache regenerates it byte-identically
    // (and recompiles nothing — every point is a disk metrics hit).
    let dc2 = DiskCache::at(&dir);
    let again = cascade::explore::run(&spec, &ctx, 2, Some(&dc2));
    assert_eq!(again.stats.misses, 0);
    let (md2, json2, _) = report::render_report(&spec, &again.results, None);
    assert_eq!(md1, md2);
    assert_eq!(json1.to_string_pretty(), json2.to_string_pretty());
    let _ = std::fs::remove_dir_all(&dir);
}
