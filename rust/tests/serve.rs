//! End-to-end acceptance for the `cascade serve` daemon (ISSUE 5, driven
//! through the ISSUE 7 keep-alive [`Client`] API):
//!
//! * K identical *concurrent* `compile` requests deduplicate to exactly
//!   one fresh compile (`CacheStats::misses == 1`, observed through the
//!   daemon's `stat` response and the on-disk record count);
//! * a daemon-served `encode` emits bytes **identical** to offline
//!   `cascade encode --from-cache` (the store-rehydrate + encode path)
//!   *and* to a wholly fresh compile of the same point — and the daemon's
//!   reported effective key matches the CLI's own key derivation;
//! * `shutdown` drains gracefully: every in-flight request is answered
//!   and [`Server::run`] returns.
//!
//! All tests skip (with a note) when the environment has no loopback
//! networking; the toolkit itself never requires a network.

use std::sync::Mutex;
use std::time::Duration;

use cascade::explore::{runner, DiskCache};
use cascade::pipeline::CompileCtx;
use cascade::serve::proto::PointQuery;
use cascade::serve::{Client, ClientOpts, ServeConfig, Server};
use cascade::sim::encode::encode_compiled;
use cascade::util::json::Json;

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cascade-serve-e2e-{tag}-{}", std::process::id()))
}

fn config(dir: &std::path::Path, workers: usize) -> ServeConfig {
    let mut cfg = ServeConfig::new("127.0.0.1:0");
    cfg.workers = workers;
    cfg.queue_cap = workers * 4;
    cfg.cache_dir = dir.to_path_buf();
    cfg
}

fn bind_or_skip(cfg: ServeConfig) -> Option<Server> {
    match Server::bind(cfg) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping serve e2e: {e}");
            None
        }
    }
}

fn tiny_point() -> PointQuery {
    PointQuery {
        app: "gaussian".into(),
        level: Some("compute".into()),
        seed: Some(1),
        fast: true,
        tiny: true,
        ..PointQuery::default()
    }
}

fn opts() -> ClientOpts {
    ClientOpts { timeout: Duration::from_secs(300), ..ClientOpts::default() }
}

#[test]
fn k_concurrent_identical_compiles_are_one_cache_miss() {
    let dir = tmp("dedup");
    let _ = std::fs::remove_dir_all(&dir);
    let ctx = CompileCtx::paper();
    let Some(server) = bind_or_skip(config(&dir, 4)) else { return };
    let addr = server.addr().to_string();
    let q = tiny_point();

    const K: usize = 4;
    let provenances: Mutex<Vec<String>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        s.spawn(|| server.run(&ctx).unwrap());
        std::thread::scope(|cs| {
            for _ in 0..K {
                cs.spawn(|| {
                    // One connection per racer: the race is between
                    // connections, the dedup is inside the daemon.
                    let mut c = Client::connect(addr.as_str(), opts()).expect("connect");
                    let r = c.compile(&q).expect("compile request");
                    assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r:?}");
                    let p = r.get("provenance").and_then(Json::as_str).unwrap().to_string();
                    assert!(r.get("metrics").is_some(), "compile response carries metrics");
                    provenances.lock().unwrap().push(p);
                });
            }
        });

        // The acceptance criterion, as the daemon accounts it: exactly
        // one fresh compile across the K identical requests. `stat` and
        // `shutdown` ride one kept-alive connection.
        let mut c = Client::connect(addr.as_str(), opts()).expect("connect");
        let stat = c.stat().expect("stat");
        let srv = stat.get("server").expect("server section");
        assert_eq!(
            srv.get("fresh_compiles").and_then(Json::as_u64),
            Some(1),
            "K identical concurrent compiles must be exactly one cache miss: {stat:?}"
        );

        let bye = c.shutdown().expect("shutdown");
        assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    });

    let provenances = provenances.into_inner().unwrap();
    assert_eq!(provenances.len(), K);
    let fresh = provenances.iter().filter(|p| p.as_str() == "fresh").count();
    assert_eq!(fresh, 1, "exactly one client pays the compile: {provenances:?}");
    for p in &provenances {
        assert!(
            ["fresh", "warm_mem", "warm_art", "warm_rec"].contains(&p.as_str()),
            "unknown provenance {p}"
        );
    }
    // The store agrees: one metrics record, one artifact.
    let dc = DiskCache::at(&dir);
    assert_eq!(dc.record_count(), 1);
    assert_eq!(dc.artifacts().keys().len(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn served_encode_matches_offline_encode_from_cache_byte_for_byte() {
    let dir = tmp("encode");
    let _ = std::fs::remove_dir_all(&dir);
    let ctx = CompileCtx::paper();
    let Some(server) = bind_or_skip(config(&dir, 2)) else { return };
    let addr = server.addr().to_string();
    let q = tiny_point();

    let mut served_key = String::new();
    let mut served_bits = String::new();
    std::thread::scope(|s| {
        s.spawn(|| server.run(&ctx).unwrap());

        // One kept-alive connection carries the whole conversation:
        // warm the store, encode by point, encode by key, shut down.
        let mut c = Client::connect(addr.as_str(), opts()).expect("connect");
        let r = c.compile(&q).unwrap();
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r:?}");
        served_key = r.get("key").and_then(Json::as_str).unwrap().to_string();

        let r2 = c.encode_point(&q).unwrap();
        assert_eq!(r2.get("ok").and_then(Json::as_bool), Some(true), "{r2:?}");
        assert_eq!(r2.get("key").and_then(Json::as_str), Some(served_key.as_str()));
        assert_eq!(
            r2.get("provenance").and_then(Json::as_str),
            Some("warm_art"),
            "the warmed store serves the encode with zero recompiles"
        );
        served_bits = r2.get("bitstream").and_then(Json::as_str).unwrap().to_string();
        assert!(r2.get("words").and_then(Json::as_u64).unwrap() > 0);

        // Key-addressed encode returns the same bytes.
        let key = u64::from_str_radix(&served_key, 16).unwrap();
        let r3 = c.encode_key(key).unwrap();
        assert_eq!(
            r3.get("bitstream").and_then(Json::as_str),
            Some(served_bits.as_str()),
            "by-key and by-point encodes must agree"
        );

        let bye = c.shutdown().unwrap();
        assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
    });

    // Offline `cascade encode --from-cache` on the same directory: the
    // daemon's key must equal the CLI's own derivation, and the
    // rehydrated artifact must encode to byte-identical text.
    let (spec, point) = q.resolve().unwrap();
    let key = runner::effective_key(&spec, &ctx.arch, &point);
    assert_eq!(format!("{key:016x}"), served_key, "daemon and CLI key derivations agree");
    let dc = DiskCache::at(&dir);
    let expect = dc.load(key).map(|m| m.artifact_fp);
    let cached = dc.artifacts().load(key, expect).expect("artifact persisted by the daemon");
    let offline = encode_compiled(&cached).to_text();
    assert_eq!(served_bits, offline, "served bitstream != offline --from-cache bitstream");

    // And both equal a wholly fresh compile of the same point.
    let (cfg, arch, _) = runner::effective_point(&spec, &ctx.arch, &point);
    let fresh_ctx = CompileCtx::new(arch);
    let fresh = runner::compile_effective(&spec, &point, &cfg, &fresh_ctx).unwrap();
    assert_eq!(offline, encode_compiled(&fresh).to_text());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shutdown_drains_and_returns() {
    let dir = tmp("drain");
    let _ = std::fs::remove_dir_all(&dir);
    let ctx = CompileCtx::paper();
    let Some(server) = bind_or_skip(config(&dir, 2)) else { return };
    let addr = server.addr().to_string();
    std::thread::scope(|s| {
        let daemon = s.spawn(|| server.run(&ctx));
        let mut c = Client::connect(addr.as_str(), opts()).unwrap();
        let r = c.ping().unwrap();
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        let bye = c.shutdown().unwrap();
        assert_eq!(bye.get("op").and_then(Json::as_str), Some("shutdown"));
        // The graceful-shutdown contract: run() itself returns cleanly.
        daemon.join().expect("daemon thread").expect("run returns Ok");
    });
    let _ = std::fs::remove_dir_all(&dir);
}
