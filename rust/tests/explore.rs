//! End-to-end test of the design-space exploration engine through its
//! public API, at test scale (64x64 frames): grid sweep, Pareto analysis,
//! pipelining dominance on critical path, persistent cache reuse,
//! byte-identical report emission across cache-served re-runs, and the
//! successive-halving search (grid agreement, budget savings, resume).

use cascade::explore::{
    report, runner, search, shard, DiskCache, EvalSession, ExploreSpec, HalvingParams,
    PartialSink, Scale, SearchKind, ShardSpec,
};
use cascade::pipeline::CompileCtx;

fn tiny_spec() -> ExploreSpec {
    ExploreSpec::default()
        .with_apps(["gaussian"])
        .with_levels(["none", "compute"])
        .with_seeds([1])
        .with_fast(true)
        .with_scale(Scale::Tiny)
}

#[test]
fn explore_end_to_end_pareto_cache_and_determinism() {
    let ctx = CompileCtx::paper();
    let spec = tiny_spec();

    let dir = std::env::temp_dir().join(format!("cascade-explore-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // First invocation: everything compiles fresh.
    let dc = DiskCache::at(&dir);
    let first = runner::run(&spec, &ctx, 2, Some(&dc));
    assert_eq!(first.stats.disk_hits, 0);
    assert!(first.stats.misses > 0);

    // Pipelining dominates the baseline on critical-path delay.
    let crit_of = |level: &str| {
        first
            .results
            .iter()
            .find(|r| r.point.level == level)
            .unwrap()
            .metrics
            .as_ref()
            .unwrap()
            .crit_ns
    };
    assert!(
        crit_of("compute") < crit_of("none"),
        "compute pipelining must shorten the critical path: {} vs {}",
        crit_of("compute"),
        crit_of("none")
    );

    // The pipelined point wins delay (asserted above) and so is always on
    // the frontier; the baseline survives only through its smaller
    // pipelining-register footprint.
    let analyses = report::analyze(&spec, &first.results);
    assert_eq!(analyses.len(), 1);
    let by_level = |level: &str| {
        first.results.iter().find(|r| r.point.level == level).unwrap()
    };
    let compute = by_level("compute");
    let none = by_level("none");
    assert!(analyses[0].frontier.contains(&compute.point.id));
    let regs = |r: &runner::PointResult| r.metrics.as_ref().unwrap().pipe_regs;
    if regs(none) < regs(compute) {
        assert!(analyses[0].frontier.contains(&none.point.id));
    }
    assert!(analyses[0].knee.is_some());
    assert!(analyses[0].capped.is_empty());
    assert!(analyses[0].failed.is_empty());

    let json1 = report::to_json(&spec, &first.results, &analyses).to_string_pretty();
    let md1 = report::to_markdown(&spec, &first.results, &analyses);

    // Second invocation: served entirely from the persistent cache, with
    // byte-identical reports.
    let dc2 = DiskCache::at(&dir);
    let second = runner::run(&spec, &ctx, 2, Some(&dc2));
    assert_eq!(second.stats.disk_hits, first.results.len());
    assert_eq!(second.stats.misses, 0);
    let analyses2 = report::analyze(&spec, &second.results);
    let json2 = report::to_json(&spec, &second.results, &analyses2).to_string_pretty();
    let md2 = report::to_markdown(&spec, &second.results, &analyses2);
    assert_eq!(json1, json2, "cache-served re-run must emit identical JSON");
    assert_eq!(md1, md2, "cache-served re-run must emit identical markdown");

    let _ = std::fs::remove_dir_all(&dir);
}

/// Coordinates that identify a point independent of its budget axis.
fn coords(r: &runner::PointResult) -> (String, String, Option<u64>, u64) {
    (
        r.point.app.clone(),
        r.point.level.clone(),
        r.point.alpha.map(f64::to_bits),
        r.point.seed,
    )
}

/// The headline acceptance criterion: on a space whose cheap fidelity
/// already separates winners, `--search halving` evaluates strictly fewer
/// full-budget points than the grid while reporting the same knee point.
#[test]
fn halving_agrees_with_grid_knee_with_fewer_full_budget_evals() {
    let ctx = CompileCtx::paper();
    let spec = tiny_spec();
    let params = HalvingParams { eta: 2, ..Default::default() };

    let grid = runner::run(&spec, &ctx, 2, None);
    let grid_analyses = report::analyze(&spec, &grid.results);
    let grid_knee = grid_analyses[0].knee.expect("grid knee");
    let grid_knee_coords = coords(
        grid.results.iter().find(|r| r.point.id == grid_knee).unwrap(),
    );

    let halved = search::run_halving(&spec, &ctx, 2, None, None, &params, None).unwrap();
    assert!(
        halved.full_budget_evals() < grid.results.len(),
        "halving must compile fewer full-budget points: {} vs {}",
        halved.full_budget_evals(),
        grid.results.len()
    );
    // Rung budgets strictly increase up to the full budget.
    let budgets: Vec<usize> = halved.rungs.iter().map(|r| r.budget).collect();
    for w in budgets.windows(2) {
        assert!(w[0] < w[1], "{budgets:?}");
    }
    assert_eq!(*budgets.last().unwrap(), search::full_budget(&spec));

    let halved_analyses = report::analyze(&spec, &halved.results);
    let halved_knee = halved_analyses[0].knee.expect("halving knee");
    let halved_knee_coords = coords(
        halved.results.iter().find(|r| r.point.id == halved_knee).unwrap(),
    );
    assert_eq!(
        halved_knee_coords, grid_knee_coords,
        "halving must report the grid's knee point"
    );
}

/// Determinism: the adaptive search promotes the same candidates and
/// reports the same metrics regardless of worker count.
#[test]
fn halving_deterministic_across_thread_counts() {
    let ctx = CompileCtx::paper();
    let spec = tiny_spec();
    let params = HalvingParams { eta: 2, ..Default::default() };
    let one = search::run_halving(&spec, &ctx, 1, None, None, &params, None).unwrap();
    let four = search::run_halving(&spec, &ctx, 4, None, None, &params, None).unwrap();
    assert_eq!(one.rungs, four.rungs);
    assert_eq!(one.results.len(), four.results.len());
    for (a, b) in one.results.iter().zip(&four.results) {
        assert_eq!(a.point, b.point);
        assert_eq!(a.metrics.as_ref().ok(), b.metrics.as_ref().ok());
    }
    assert_eq!(one.stats, four.stats);
}

/// Resume: a run killed after rung 0 leaves disk-cache records behind;
/// the re-run is served from them (here the whole ladder collapses onto
/// the rung-0 artifacts because neither level has a post-PnR pass).
#[test]
fn halving_resumes_from_partial_rung_work() {
    let ctx = CompileCtx::paper();
    let spec = tiny_spec();
    let params = HalvingParams { eta: 2, ..Default::default() };
    let dir = std::env::temp_dir().join(format!("cascade-halving-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // "First run": evaluate only rung 0, then die.
    let candidates = spec.candidates();
    let budgets = search::rung_budgets(
        search::full_budget(&spec),
        params.min_budget,
        params.eta,
        candidates.len(),
    );
    assert!(budgets.len() >= 2, "need a real ladder for this test: {budgets:?}");
    let rung0: Vec<_> = candidates.iter().map(|c| c.at_budget(budgets[0])).collect();
    {
        let dc = DiskCache::at(&dir);
        let session = EvalSession::new(&spec, &ctx, Some(&dc), None);
        let results = session.eval_points(&rung0, 2, Some(0));
        assert!(results.iter().all(|r| r.metrics.is_ok()));
        assert_eq!(session.stats().disk_hits, 0);
    }

    // Re-run the full search against the same cache directory: every
    // evaluation is a disk hit, nothing recompiles.
    let dc = DiskCache::at(&dir);
    let out = search::run_halving(&spec, &ctx, 2, Some(&dc), None, &params, None).unwrap();
    assert_eq!(out.stats.misses, 0, "resume must not recompile rung-0 work");
    assert_eq!(out.stats.disk_hits, out.total_evals());
    assert!(out.results.iter().all(|r| r.from_disk));

    let _ = std::fs::remove_dir_all(&dir);
}

/// The streamed partial log records one line per evaluation, rung-tagged.
#[test]
fn halving_streams_partial_results() {
    let ctx = CompileCtx::paper();
    let spec = tiny_spec();
    let params = HalvingParams { eta: 2, ..Default::default() };
    let path = std::env::temp_dir()
        .join(format!("cascade-halving-partial-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let sink = PartialSink::open(&path);
    let out = search::run_halving(&spec, &ctx, 2, None, Some(&sink), &params, None).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), out.total_evals());
    assert!(text.contains("\"rung\":0"));
    assert!(text.contains(&format!("\"rung\":{}", out.rungs.len() - 1)));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn power_cap_filters_frontier_points() {
    let ctx = CompileCtx::paper();
    // A cap below any plausible estimate (static floor is 15 mW) makes
    // every point infeasible; the frontier must come out empty rather
    // than ranking infeasible designs.
    let spec = tiny_spec().with_levels(["none"]).with_power_cap(Some(1.0));
    let out = runner::run(&spec, &ctx, 1, None);
    let analyses = report::analyze(&spec, &out.results);
    assert!(analyses[0].frontier.is_empty());
    assert_eq!(analyses[0].capped.len(), out.results.len());
    let json = report::to_json(&spec, &out.results, &analyses).to_string_compact();
    assert_eq!(json.matches("\"capped\"").count(), 1);
}

/// The tentpole acceptance criterion: `--shard K/N` for N in {1, 3}
/// followed by `explore-merge` reproduces the unsharded grid report —
/// knee, frontier, markdown and JSON — byte for byte.
#[test]
fn sharded_grid_merge_is_bit_identical_to_unsharded() {
    let ctx = CompileCtx::paper();
    let spec = tiny_spec();
    let root = std::env::temp_dir().join(format!("cascade-shard-grid-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);

    // Reference: unsharded run through the shared render path.
    let reference = runner::run(&spec, &ctx, 2, None);
    let (ref_md, ref_json, ref_analyses) = report::render_report(&spec, &reference.results, None);
    assert!(ref_analyses[0].knee.is_some());

    for n in [1usize, 3] {
        let mut owned_total = 0;
        let dirs: Vec<std::path::PathBuf> = (1..=n)
            .map(|k| {
                let dir = root.join(format!("grid-{n}-{k}"));
                let sh = ShardSpec { index: k, count: n };
                let out =
                    shard::run_sharded(&spec, &ctx, 2, &SearchKind::Grid, &sh, &dir).unwrap();
                assert_eq!(out.manifest.points_total, spec.points().len());
                assert!(dir.join(sh.manifest_name()).is_file());
                owned_total += out.manifest.points.len();
                dir
            })
            .collect();
        assert_eq!(owned_total, spec.points().len(), "shards must partition the space");

        let out_dir = root.join(format!("grid-merged-{n}"));
        let merged = shard::merge(&dirs, &ctx.arch, &out_dir).unwrap();
        assert_eq!(merged.shards, n);
        let (md, json, _) = report::render_report(&merged.spec, &merged.results, None);
        assert_eq!(md, ref_md, "N={n}: merged markdown must be byte-identical");
        assert_eq!(
            json.to_string_pretty(),
            ref_json.to_string_pretty(),
            "N={n}: merged JSON must be byte-identical"
        );
    }
    let _ = std::fs::remove_dir_all(&root);
}

/// Sharded successive halving: lower rungs replay deterministically on
/// every shard, the top rung is partitioned, and the merged report (knee,
/// trajectory and all) is byte-identical to the single-process search.
#[test]
fn sharded_halving_merge_matches_unsharded_report() {
    let ctx = CompileCtx::paper();
    let spec = tiny_spec();
    let params = HalvingParams { eta: 2, ..Default::default() };
    let root = std::env::temp_dir().join(format!("cascade-shard-halving-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);

    let reference = search::run_halving(&spec, &ctx, 2, None, None, &params, None).unwrap();
    let (ref_md, ref_json, _) = report::render_report(
        &spec,
        &reference.results,
        Some((&params, reference.rungs.as_slice())),
    );

    let n = 3;
    let search_kind = SearchKind::Halving(params.clone());
    let mut owned_total = 0;
    let dirs: Vec<std::path::PathBuf> = (1..=n)
        .map(|k| {
            let dir = root.join(format!("halving-{k}"));
            let sh = ShardSpec { index: k, count: n };
            let out = shard::run_sharded(&spec, &ctx, 2, &search_kind, &sh, &dir).unwrap();
            // Every shard independently derives the same global outcome.
            assert_eq!(out.manifest.rungs.as_deref(), Some(reference.rungs.as_slice()));
            assert_eq!(
                out.manifest.survivor_ids.as_deref().unwrap(),
                reference.results.iter().map(|r| r.point.id).collect::<Vec<_>>().as_slice()
            );
            owned_total += out.manifest.points.len();
            dir
        })
        .collect();
    assert_eq!(owned_total, reference.results.len(), "top rung must partition the survivors");

    let out_dir = root.join("merged");
    let merged = shard::merge(&dirs, &ctx.arch, &out_dir).unwrap();
    let trajectory = merged.trajectory.as_ref().map(|(p, r)| (p, r.as_slice()));
    let (md, json, analyses) = report::render_report(&merged.spec, &merged.results, trajectory);
    assert_eq!(md, ref_md, "merged halving markdown must be byte-identical");
    assert_eq!(json.to_string_pretty(), ref_json.to_string_pretty());
    assert!(analyses[0].knee.is_some(), "merged run must still produce a knee point");

    // The merged partial log concatenates every shard's journal with
    // shard tags intact; lower rungs appear once per shard by design.
    let log = std::fs::read_to_string(out_dir.join("explore_partial.jsonl")).unwrap();
    for k in 1..=n {
        assert!(log.contains(&format!("\"shard\":\"{k}/{n}\"")), "missing shard {k} lines");
    }
    let _ = std::fs::remove_dir_all(&root);
}

/// The incremental kernels (delta-cost placement, selective rip-up
/// bookkeeping, dirty-set STA) are pure memoization: the global
/// `--no-incremental` escape hatch must reproduce every output byte for
/// byte — explore reports and encoded bitstreams alike. This is the
/// end-to-end side of the contract written down in `docs/performance.md`;
/// the per-kernel equivalence lives in the `pnr::place`, `pnr::route` and
/// `timing::sta` unit tests.
#[test]
fn no_incremental_flag_reproduces_reports_and_bitstreams_byte_for_byte() {
    use std::process::Command;

    let bin = env!("CARGO_BIN_EXE_cascade");
    let root = std::env::temp_dir().join(format!("cascade-noinc-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);

    // Each invocation gets its own working directory: `results/` (reports,
    // cache, bitstreams) is cwd-relative, so the two modes cannot share
    // state — any agreement below is computed, not cached.
    let run = |mode: &str, base_args: &[&str], extra: &[&str]| -> std::path::PathBuf {
        let dir = root.join(mode);
        std::fs::create_dir_all(&dir).unwrap();
        let out = Command::new(bin)
            .current_dir(&dir)
            .args(base_args)
            .args(extra)
            .output()
            .expect("spawn cascade");
        assert!(
            out.status.success(),
            "cascade {base_args:?} {extra:?} failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
        dir
    };

    let explore_args = [
        "explore", "--apps", "gaussian", "--levels", "none,compute", "--seeds", "1",
        "--tiny", "--fast", "--threads", "2", "--no-cache",
    ];
    let fast = run("explore-fast", &explore_args, &[]);
    let slow = run("explore-slow", &explore_args, &["--no-incremental"]);
    for name in ["results/explore.json", "results/explore.md"] {
        let a = std::fs::read(fast.join(name)).unwrap();
        let b = std::fs::read(slow.join(name)).unwrap();
        assert!(!a.is_empty(), "{name} must not be empty");
        assert_eq!(a, b, "{name} must be byte-identical under --no-incremental");
    }

    let encode_args = [
        "encode", "--app", "gaussian", "--level", "compute", "--seed", "1", "--tiny",
        "--fast", "--out", "bits.txt",
    ];
    let fast = run("encode-fast", &encode_args, &[]);
    let slow = run("encode-slow", &encode_args, &["--no-incremental"]);
    let a = std::fs::read(fast.join("bits.txt")).unwrap();
    let b = std::fs::read(slow.join("bits.txt")).unwrap();
    assert!(!a.is_empty(), "bitstream must not be empty");
    assert_eq!(a, b, "bitstream must be byte-identical under --no-incremental");

    let _ = std::fs::remove_dir_all(&root);
}

/// The `--fuse` axis participates in the shard manifest fingerprint:
/// fused and unfused compiles are semantically equivalent but produce
/// structurally different artifacts, so an `explore-merge` over shards
/// that disagree on the fusion setting must abort as spec drift instead
/// of silently mixing the two cohorts.
#[test]
fn merge_rejects_shards_with_mixed_fusion_settings() {
    let ctx = CompileCtx::paper();
    let root =
        std::env::temp_dir().join(format!("cascade-shard-fusedrift-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);

    let spec_off = tiny_spec();
    let spec_on = tiny_spec().with_fuses([true]);

    let dir1 = root.join("shard-1");
    let dir2 = root.join("shard-2");
    let sh1 = ShardSpec { index: 1, count: 2 };
    let sh2 = ShardSpec { index: 2, count: 2 };
    shard::run_sharded(&spec_off, &ctx, 2, &SearchKind::Grid, &sh1, &dir1).unwrap();
    shard::run_sharded(&spec_on, &ctx, 2, &SearchKind::Grid, &sh2, &dir2).unwrap();

    let err = shard::merge(&[dir1, dir2], &ctx.arch, &root.join("merged")).unwrap_err();
    assert!(err.contains("spec drift"), "expected a spec-drift abort, got: {err}");
    let _ = std::fs::remove_dir_all(&root);
}
