//! End-to-end test of the design-space exploration engine through its
//! public API, at test scale (64x64 frames): grid sweep, Pareto analysis,
//! pipelining dominance on critical path, persistent cache reuse, and
//! byte-identical report emission across cache-served re-runs.

use cascade::explore::{report, runner, DiskCache, ExploreSpec, Scale};
use cascade::pipeline::CompileCtx;

fn tiny_spec() -> ExploreSpec {
    ExploreSpec::default()
        .with_apps(["gaussian"])
        .with_levels(["none", "compute"])
        .with_seeds([1])
        .with_fast(true)
        .with_scale(Scale::Tiny)
}

#[test]
fn explore_end_to_end_pareto_cache_and_determinism() {
    let ctx = CompileCtx::paper();
    let spec = tiny_spec();

    let dir = std::env::temp_dir().join(format!("cascade-explore-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // First invocation: everything compiles fresh.
    let dc = DiskCache::at(&dir);
    let first = runner::run(&spec, &ctx, 2, Some(&dc));
    assert_eq!(first.stats.disk_hits, 0);
    assert!(first.stats.misses > 0);

    // Pipelining dominates the baseline on critical-path delay.
    let crit_of = |level: &str| {
        first
            .results
            .iter()
            .find(|r| r.point.level == level)
            .unwrap()
            .metrics
            .as_ref()
            .unwrap()
            .crit_ns
    };
    assert!(
        crit_of("compute") < crit_of("none"),
        "compute pipelining must shorten the critical path: {} vs {}",
        crit_of("compute"),
        crit_of("none")
    );

    // The pipelined point wins delay (asserted above) and so is always on
    // the frontier; the baseline survives only through its smaller
    // pipelining-register footprint.
    let analyses = report::analyze(&spec, &first.results);
    assert_eq!(analyses.len(), 1);
    let by_level = |level: &str| {
        first.results.iter().find(|r| r.point.level == level).unwrap()
    };
    let compute = by_level("compute");
    let none = by_level("none");
    assert!(analyses[0].frontier.contains(&compute.point.id));
    let regs = |r: &runner::PointResult| r.metrics.as_ref().unwrap().pipe_regs;
    if regs(none) < regs(compute) {
        assert!(analyses[0].frontier.contains(&none.point.id));
    }
    assert!(analyses[0].knee.is_some());
    assert!(analyses[0].capped.is_empty());
    assert!(analyses[0].failed.is_empty());

    let json1 = report::to_json(&spec, &first.results, &analyses).to_string_pretty();
    let md1 = report::to_markdown(&spec, &first.results, &analyses);

    // Second invocation: served entirely from the persistent cache, with
    // byte-identical reports.
    let dc2 = DiskCache::at(&dir);
    let second = runner::run(&spec, &ctx, 2, Some(&dc2));
    assert_eq!(second.stats.disk_hits, first.results.len());
    assert_eq!(second.stats.misses, 0);
    let analyses2 = report::analyze(&spec, &second.results);
    let json2 = report::to_json(&spec, &second.results, &analyses2).to_string_pretty();
    let md2 = report::to_markdown(&spec, &second.results, &analyses2);
    assert_eq!(json1, json2, "cache-served re-run must emit identical JSON");
    assert_eq!(md1, md2, "cache-served re-run must emit identical markdown");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn power_cap_filters_frontier_points() {
    let ctx = CompileCtx::paper();
    // A cap below any plausible estimate (static floor is 15 mW) makes
    // every point infeasible; the frontier must come out empty rather
    // than ranking infeasible designs.
    let spec = tiny_spec().with_levels(["none"]).with_power_cap(Some(1.0));
    let out = runner::run(&spec, &ctx, 1, None);
    let analyses = report::analyze(&spec, &out.results);
    assert!(analyses[0].frontier.is_empty());
    assert_eq!(analyses[0].capped.len(), out.results.len());
    let json = report::to_json(&spec, &out.results, &analyses).to_string_compact();
    assert_eq!(json.matches("\"capped\"").count(), 1);
}
