//! End-to-end acceptance for the observability subsystem (ISSUE 6):
//!
//! * per-stage compile spans are contiguous: their sum reaches within 5%
//!   of the wall-clock compile time (nothing substantial goes untraced);
//! * tracing **never perturbs outputs** — a traced compile produces a
//!   bitstream byte-identical to an untraced one;
//! * a served compile/encode round trip leaves a scrapeable `metrics`
//!   exposition (request counters, provenance counters, stage
//!   histograms), splits response timing into `queue_ms` + `exec_ms`
//!   with `ms` their sum, and writes the structured JSONL request log
//!   with `start`/`request`/`drain` events.
//!
//! Serve tests skip (with a note) when the environment has no loopback
//! networking, mirroring `tests/serve.rs`.

use std::time::{Duration, Instant};

use cascade::obs::{with_spans, STAGE_ORDER};
use cascade::pipeline::{compile, CompileCtx, PipelineConfig};
use cascade::serve::proto::PointQuery;
use cascade::serve::{Client, ClientOpts, ServeConfig, Server};
use cascade::sim::encode::encode_compiled;
use cascade::util::json::Json;

#[test]
fn compile_stage_spans_sum_to_wall_clock_within_5_percent() {
    let ctx = CompileCtx::paper();
    let app = cascade::apps::dense::gaussian(64, 64, 2);
    let cfg = PipelineConfig::with_postpnr();
    let t0 = Instant::now();
    let (compiled, spans) = with_spans(|| compile(&app, &ctx, &cfg, 3));
    let wall = t0.elapsed().as_nanos() as u64;
    compiled.expect("compile succeeds under tracing");

    assert!(!spans.is_empty(), "a traced compile must mark stages");
    for s in &spans {
        assert!(STAGE_ORDER.contains(&s.stage), "unknown stage '{}'", s.stage);
    }
    let named: Vec<&str> = spans.iter().map(|s| s.stage).collect();
    for stage in ["map", "pipeline", "schedule", "place", "route", "sta"] {
        assert!(named.contains(&stage), "stage '{stage}' missing from {named:?}");
    }

    // The lap clock is contiguous from installation to the last mark, so
    // the spans must account for (almost) the whole enclosing wall time.
    let sum: u64 = spans.iter().map(|s| s.nanos).sum();
    assert!(sum <= wall, "laps cannot exceed the enclosing wall clock");
    let gap = wall - sum;
    assert!(
        (gap as f64) <= 0.05 * (wall as f64),
        "untraced gap {gap} ns of {wall} ns wall (> 5%): {spans:?}"
    );
}

#[test]
fn tracing_never_perturbs_compile_outputs() {
    let ctx = CompileCtx::paper();
    let app = cascade::apps::dense::gaussian(64, 64, 2);
    let cfg = PipelineConfig::with_postpnr();

    let plain = compile(&app, &ctx, &cfg, 3).expect("untraced compile");
    let (traced, spans) = with_spans(|| compile(&app, &ctx, &cfg, 3));
    let traced = traced.expect("traced compile");

    assert!(!spans.is_empty());
    assert_eq!(
        encode_compiled(&plain).to_text(),
        encode_compiled(&traced).to_text(),
        "tracing changed the bitstream"
    );
    assert_eq!(plain.fmax_mhz(), traced.fmax_mhz(), "tracing changed timing results");
}

// ---------------------------------------------------------------------
// Served observability
// ---------------------------------------------------------------------

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cascade-obs-e2e-{tag}-{}", std::process::id()))
}

fn bind_or_skip(cfg: ServeConfig) -> Option<Server> {
    match Server::bind(cfg) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping obs serve e2e: {e}");
            None
        }
    }
}

fn tiny_point() -> PointQuery {
    PointQuery {
        app: "gaussian".into(),
        level: Some("compute".into()),
        seed: Some(1),
        fast: true,
        tiny: true,
        ..PointQuery::default()
    }
}

fn opts() -> ClientOpts {
    ClientOpts { timeout: Duration::from_secs(300), ..ClientOpts::default() }
}

#[test]
fn served_metrics_timing_split_and_request_log() {
    let dir = tmp("metrics");
    let _ = std::fs::remove_dir_all(&dir);
    let ctx = CompileCtx::paper();
    let mut cfg = ServeConfig::new("127.0.0.1:0");
    cfg.workers = 2;
    cfg.queue_cap = 8;
    cfg.cache_dir = dir.clone();
    let Some(server) = bind_or_skip(cfg) else { return };
    let addr = server.addr().to_string();
    let q = tiny_point();

    let mut exposition = String::new();
    std::thread::scope(|s| {
        let daemon = s.spawn(|| server.run(&ctx));

        // One fresh compile, then an encode served from the warm store —
        // all on one kept-alive connection.
        let mut c = Client::connect(addr.as_str(), opts()).unwrap();
        let r = c.compile(&q).unwrap();
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r:?}");
        let queue_ms = r.get("queue_ms").and_then(Json::as_f64).expect("queue_ms");
        let exec_ms = r.get("exec_ms").and_then(Json::as_f64).expect("exec_ms");
        let ms = r.get("ms").and_then(Json::as_f64).expect("ms");
        assert!(queue_ms >= 0.0 && exec_ms > 0.0);
        assert!(
            (queue_ms + exec_ms - ms).abs() < 1e-6,
            "ms must be the sum of queue_ms and exec_ms: {queue_ms} + {exec_ms} != {ms}"
        );

        let r2 = c.encode_point(&q).unwrap();
        assert_eq!(r2.get("ok").and_then(Json::as_bool), Some(true), "{r2:?}");
        assert!(r2.get("queue_ms").is_some() && r2.get("exec_ms").is_some());

        let m = c.metrics().unwrap();
        assert_eq!(m.get("ok").and_then(Json::as_bool), Some(true), "{m:?}");
        exposition = m.get("exposition").and_then(Json::as_str).expect("exposition").to_string();

        let bye = c.shutdown().unwrap();
        assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
        daemon.join().expect("daemon thread").expect("run returns Ok");
    });

    // The exposition names the series the CI smoke job greps for.
    for needle in [
        "# TYPE serve_requests_total counter",
        "serve_requests_total{op=\"compile\"} 1",
        "serve_requests_total{op=\"encode\"} 1",
        "serve_provenance_total{provenance=\"fresh\"} 1",
        "compile_stage_seconds{stage=\"map\"}",
        "compile_stage_seconds{stage=\"sta\"}",
        "compile_seconds_count 1",
        "encode_seconds_count 1",
        "serve_queue_seconds_count",
        "serve_request_queue_seconds_count",
        "cache_fresh_compiles 1",
    ] {
        assert!(exposition.contains(needle), "exposition lacks {needle:?}:\n{exposition}");
    }

    // The request log holds structured records: a start event, one line
    // per request (with the timing split and provenance), and the drain.
    let log = std::fs::read_to_string(dir.join("serve_requests.jsonl")).expect("request log");
    let recs: Vec<Json> =
        log.lines().map(|l| Json::parse(l).expect("request-log line parses")).collect();
    assert!(recs.len() >= 6, "start + 4 requests + drain expected:\n{log}");
    let events: Vec<&str> =
        recs.iter().map(|r| r.get("event").and_then(Json::as_str).unwrap()).collect();
    assert_eq!(events.first(), Some(&"start"));
    assert_eq!(events.last(), Some(&"drain"));
    let compile_rec = recs
        .iter()
        .find(|r| r.get("op").and_then(Json::as_str) == Some("compile"))
        .expect("compile record");
    assert_eq!(compile_rec.get("outcome").and_then(Json::as_str), Some("ok"));
    assert_eq!(compile_rec.get("provenance").and_then(Json::as_str), Some("fresh"));
    assert!(compile_rec.get("key").and_then(Json::as_str).is_some());
    assert!(compile_rec.get("exec_ms").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(compile_rec.get("ts").and_then(Json::as_u64).unwrap() > 0);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn served_outputs_identical_with_log_disabled() {
    // The observability layer must never perturb outputs: the same point
    // served by a logless daemon yields the same key and bitstream.
    let dir_a = tmp("perturb-a");
    let dir_b = tmp("perturb-b");
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
    let ctx = CompileCtx::paper();
    let q = tiny_point();

    let mut outputs: Vec<(String, String)> = Vec::new();
    for (dir, log) in
        [(&dir_a, cascade::serve::LogTarget::Default), (&dir_b, cascade::serve::LogTarget::Disabled)]
    {
        let mut cfg = ServeConfig::new("127.0.0.1:0");
        cfg.workers = 1;
        cfg.cache_dir = dir.to_path_buf();
        cfg.log = log;
        let Some(server) = bind_or_skip(cfg) else { return };
        let addr = server.addr().to_string();
        std::thread::scope(|s| {
            s.spawn(|| server.run(&ctx).unwrap());
            let mut c = Client::connect(addr.as_str(), opts()).unwrap();
            let r = c.encode_point(&q).unwrap();
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r:?}");
            outputs.push((
                r.get("key").and_then(Json::as_str).unwrap().to_string(),
                r.get("bitstream").and_then(Json::as_str).unwrap().to_string(),
            ));
            let bye = c.shutdown().unwrap();
            assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
        });
    }

    assert_eq!(outputs.len(), 2);
    assert_eq!(outputs[0].0, outputs[1].0, "key differs between logged and logless daemons");
    assert_eq!(outputs[0].1, outputs[1].1, "bitstream differs between logged and logless daemons");
    assert!(
        !dir_b.join("serve_requests.jsonl").exists(),
        "--log none must write no request log"
    );
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}
