//! End-to-end acceptance for the observability subsystem (ISSUE 6):
//!
//! * per-stage compile spans are contiguous: their sum reaches within 5%
//!   of the wall-clock compile time (nothing substantial goes untraced);
//! * tracing **never perturbs outputs** — a traced compile produces a
//!   bitstream byte-identical to an untraced one;
//! * a served compile/encode round trip leaves a scrapeable `metrics`
//!   exposition (request counters, provenance counters, stage
//!   histograms), splits response timing into `queue_ms` + `exec_ms`
//!   with `ms` their sum, and writes the structured JSONL request log
//!   with `start`/`request`/`drain` events.
//!
//! ISSUE 10 adds the distributed half: a routed compile's request-log
//! record carries one **span tree** covering both hops with per-stage
//! kernel counters attached; the kernel counters agree with ground-truth
//! recounts; and instrumentation (tracing, counters, request log) stays
//! byte-invisible in explore reports and routed bitstreams.
//!
//! Serve tests skip (with a note) when the environment has no loopback
//! networking, mirroring `tests/serve.rs`.

use std::time::{Duration, Instant};

use cascade::obs::{with_counters, with_spans, STAGE_ORDER};
use cascade::pipeline::{compile, CompileCtx, PipelineConfig};
use cascade::serve::proto::{trace_from_json, PointQuery, TraceSpan};
use cascade::serve::{Client, ClientOpts, LogTarget, ServeConfig, Server};
use cascade::sim::encode::encode_compiled;
use cascade::util::json::Json;

#[test]
fn compile_stage_spans_sum_to_wall_clock_within_5_percent() {
    let ctx = CompileCtx::paper();
    let app = cascade::apps::dense::gaussian(64, 64, 2);
    let cfg = PipelineConfig::with_postpnr();
    let t0 = Instant::now();
    let (compiled, spans) = with_spans(|| compile(&app, &ctx, &cfg, 3));
    let wall = t0.elapsed().as_nanos() as u64;
    compiled.expect("compile succeeds under tracing");

    assert!(!spans.is_empty(), "a traced compile must mark stages");
    for s in &spans {
        assert!(STAGE_ORDER.contains(&s.stage), "unknown stage '{}'", s.stage);
    }
    let named: Vec<&str> = spans.iter().map(|s| s.stage).collect();
    for stage in ["map", "pipeline", "schedule", "place", "route", "sta"] {
        assert!(named.contains(&stage), "stage '{stage}' missing from {named:?}");
    }

    // The lap clock is contiguous from installation to the last mark, so
    // the spans must account for (almost) the whole enclosing wall time.
    let sum: u64 = spans.iter().map(|s| s.nanos).sum();
    assert!(sum <= wall, "laps cannot exceed the enclosing wall clock");
    let gap = wall - sum;
    assert!(
        (gap as f64) <= 0.05 * (wall as f64),
        "untraced gap {gap} ns of {wall} ns wall (> 5%): {spans:?}"
    );
}

#[test]
fn tracing_never_perturbs_compile_outputs() {
    let ctx = CompileCtx::paper();
    let app = cascade::apps::dense::gaussian(64, 64, 2);
    let cfg = PipelineConfig::with_postpnr();

    let plain = compile(&app, &ctx, &cfg, 3).expect("untraced compile");
    let (traced, spans) = with_spans(|| compile(&app, &ctx, &cfg, 3));
    let traced = traced.expect("traced compile");

    assert!(!spans.is_empty());
    assert_eq!(
        encode_compiled(&plain).to_text(),
        encode_compiled(&traced).to_text(),
        "tracing changed the bitstream"
    );
    assert_eq!(plain.fmax_mhz(), traced.fmax_mhz(), "tracing changed timing results");
}

// ---------------------------------------------------------------------
// Served observability
// ---------------------------------------------------------------------

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cascade-obs-e2e-{tag}-{}", std::process::id()))
}

fn bind_or_skip(cfg: ServeConfig) -> Option<Server> {
    match Server::bind(cfg) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping obs serve e2e: {e}");
            None
        }
    }
}

fn tiny_point() -> PointQuery {
    PointQuery {
        app: "gaussian".into(),
        level: Some("compute".into()),
        seed: Some(1),
        fast: true,
        tiny: true,
        ..PointQuery::default()
    }
}

fn opts() -> ClientOpts {
    ClientOpts { timeout: Duration::from_secs(300), ..ClientOpts::default() }
}

#[test]
fn served_metrics_timing_split_and_request_log() {
    let dir = tmp("metrics");
    let _ = std::fs::remove_dir_all(&dir);
    let ctx = CompileCtx::paper();
    let mut cfg = ServeConfig::new("127.0.0.1:0");
    cfg.workers = 2;
    cfg.queue_cap = 8;
    cfg.cache_dir = dir.clone();
    let Some(server) = bind_or_skip(cfg) else { return };
    let addr = server.addr().to_string();
    let q = tiny_point();

    let mut exposition = String::new();
    std::thread::scope(|s| {
        let daemon = s.spawn(|| server.run(&ctx));

        // One fresh compile, then an encode served from the warm store —
        // all on one kept-alive connection.
        let mut c = Client::connect(addr.as_str(), opts()).unwrap();
        let r = c.compile(&q).unwrap();
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r:?}");
        let queue_ms = r.get("queue_ms").and_then(Json::as_f64).expect("queue_ms");
        let exec_ms = r.get("exec_ms").and_then(Json::as_f64).expect("exec_ms");
        let ms = r.get("ms").and_then(Json::as_f64).expect("ms");
        assert!(queue_ms >= 0.0 && exec_ms > 0.0);
        assert!(
            (queue_ms + exec_ms - ms).abs() < 1e-6,
            "ms must be the sum of queue_ms and exec_ms: {queue_ms} + {exec_ms} != {ms}"
        );

        let r2 = c.encode_point(&q).unwrap();
        assert_eq!(r2.get("ok").and_then(Json::as_bool), Some(true), "{r2:?}");
        assert!(r2.get("queue_ms").is_some() && r2.get("exec_ms").is_some());

        let m = c.metrics().unwrap();
        assert_eq!(m.get("ok").and_then(Json::as_bool), Some(true), "{m:?}");
        exposition = m.get("exposition").and_then(Json::as_str).expect("exposition").to_string();

        let bye = c.shutdown().unwrap();
        assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
        daemon.join().expect("daemon thread").expect("run returns Ok");
    });

    // The exposition names the series the CI smoke job greps for.
    for needle in [
        "# TYPE serve_requests_total counter",
        "serve_requests_total{op=\"compile\"} 1",
        "serve_requests_total{op=\"encode\"} 1",
        "serve_provenance_total{provenance=\"fresh\"} 1",
        "compile_stage_seconds{stage=\"map\"}",
        "compile_stage_seconds{stage=\"sta\"}",
        "compile_seconds_count 1",
        "encode_seconds_count 1",
        "serve_queue_seconds_count",
        "serve_request_queue_seconds_count",
        "cache_fresh_compiles 1",
    ] {
        assert!(exposition.contains(needle), "exposition lacks {needle:?}:\n{exposition}");
    }

    // The request log holds structured records: a start event, one line
    // per request (with the timing split and provenance), and the drain.
    let log = std::fs::read_to_string(dir.join("serve_requests.jsonl")).expect("request log");
    let recs: Vec<Json> =
        log.lines().map(|l| Json::parse(l).expect("request-log line parses")).collect();
    assert!(recs.len() >= 6, "start + 4 requests + drain expected:\n{log}");
    let events: Vec<&str> =
        recs.iter().map(|r| r.get("event").and_then(Json::as_str).unwrap()).collect();
    assert_eq!(events.first(), Some(&"start"));
    assert_eq!(events.last(), Some(&"drain"));
    let compile_rec = recs
        .iter()
        .find(|r| r.get("op").and_then(Json::as_str) == Some("compile"))
        .expect("compile record");
    assert_eq!(compile_rec.get("outcome").and_then(Json::as_str), Some("ok"));
    assert_eq!(compile_rec.get("provenance").and_then(Json::as_str), Some("fresh"));
    assert!(compile_rec.get("key").and_then(Json::as_str).is_some());
    assert!(compile_rec.get("exec_ms").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(compile_rec.get("ts").and_then(Json::as_u64).unwrap() > 0);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn served_outputs_identical_with_log_disabled() {
    // The observability layer must never perturb outputs: the same point
    // served by a logless daemon yields the same key and bitstream.
    let dir_a = tmp("perturb-a");
    let dir_b = tmp("perturb-b");
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
    let ctx = CompileCtx::paper();
    let q = tiny_point();

    let mut outputs: Vec<(String, String)> = Vec::new();
    for (dir, log) in
        [(&dir_a, cascade::serve::LogTarget::Default), (&dir_b, cascade::serve::LogTarget::Disabled)]
    {
        let mut cfg = ServeConfig::new("127.0.0.1:0");
        cfg.workers = 1;
        cfg.cache_dir = dir.to_path_buf();
        cfg.log = log;
        let Some(server) = bind_or_skip(cfg) else { return };
        let addr = server.addr().to_string();
        std::thread::scope(|s| {
            s.spawn(|| server.run(&ctx).unwrap());
            let mut c = Client::connect(addr.as_str(), opts()).unwrap();
            let r = c.encode_point(&q).unwrap();
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r:?}");
            outputs.push((
                r.get("key").and_then(Json::as_str).unwrap().to_string(),
                r.get("bitstream").and_then(Json::as_str).unwrap().to_string(),
            ));
            let bye = c.shutdown().unwrap();
            assert_eq!(bye.get("ok").and_then(Json::as_bool), Some(true));
        });
    }

    assert_eq!(outputs.len(), 2);
    assert_eq!(outputs[0].0, outputs[1].0, "key differs between logged and logless daemons");
    assert_eq!(outputs[0].1, outputs[1].1, "bitstream differs between logged and logless daemons");
    assert!(
        !dir_b.join("serve_requests.jsonl").exists(),
        "--log none must write no request log"
    );
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

// ---------------------------------------------------------------------
// Distributed tracing + kernel counters (ISSUE 10)
// ---------------------------------------------------------------------

#[test]
fn routed_compile_logs_one_well_formed_span_tree_across_both_hops() {
    let ctx = CompileCtx::paper();
    let dirs: Vec<_> =
        ["front", "b1", "b2"].iter().map(|t| tmp(&format!("trace-{t}"))).collect();
    for d in &dirs {
        let _ = std::fs::remove_dir_all(d);
    }
    let mk = |dir: &std::path::Path| {
        let mut cfg = ServeConfig::new("127.0.0.1:0");
        cfg.workers = 2;
        cfg.queue_cap = 8;
        cfg.cache_dir = dir.to_path_buf();
        cfg
    };
    let Some(b1) = bind_or_skip(mk(&dirs[1])) else { return };
    let Some(b2) = bind_or_skip(mk(&dirs[2])) else { return };
    let backend_addrs = vec![b1.addr().to_string(), b2.addr().to_string()];

    let mut wall_ns = 0u64;
    std::thread::scope(|s| {
        s.spawn(|| b1.run(&ctx).unwrap());
        s.spawn(|| b2.run(&ctx).unwrap());
        let mut fcfg = mk(&dirs[0]);
        fcfg.route = backend_addrs.clone();
        let front = Server::bind(fcfg).expect("front binds");
        let front_addr = front.addr().to_string();
        s.spawn(|| front.run(&ctx).unwrap());

        let mut c = Client::connect(front_addr.as_str(), opts()).unwrap();
        let t0 = Instant::now();
        let r = c.compile(&tiny_point()).unwrap();
        wall_ns = t0.elapsed().as_nanos() as u64;
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r:?}");

        c.shutdown().unwrap();
        for addr in &backend_addrs {
            let mut b = Client::connect(addr.as_str(), opts()).unwrap();
            b.shutdown().unwrap();
        }
    });

    // The *front's* request log holds the whole distributed tree.
    let log = std::fs::read_to_string(dirs[0].join("serve_requests.jsonl"))
        .expect("front request log");
    let rec = log
        .lines()
        .filter_map(|l| Json::parse(l).ok())
        .find(|j| {
            j.get("op").and_then(Json::as_str) == Some("compile") && j.get("trace").is_some()
        })
        .unwrap_or_else(|| panic!("no traced compile record in front log:\n{log}"));
    let (_id, spans) = trace_from_json(rec.get("trace").unwrap()).expect("trace parses");

    // Well-formed: unique ids, a single root, every parent resolvable.
    let mut ids: Vec<u64> = spans.iter().map(|s| s.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), spans.len(), "span ids must be unique: {spans:?}");
    let roots: Vec<&TraceSpan> =
        spans.iter().filter(|s| ids.binary_search(&s.parent).is_err()).collect();
    assert_eq!(roots.len(), 1, "exactly one root span: {spans:?}");
    let root = roots[0];
    assert_eq!(root.name, "request");

    // Nesting: children never sum past their parent (5% skew allowance),
    // and the whole tree fits in the client-observed wall clock.
    for s in &spans {
        let kids: u64 = spans.iter().filter(|k| k.parent == s.id).map(|k| k.ns).sum();
        assert!(
            kids as f64 <= s.ns as f64 * 1.05,
            "children of '{}' sum to {kids} ns, past the span's own {} ns",
            s.name,
            s.ns
        );
    }
    assert!(
        root.ns as f64 <= wall_ns as f64 * 1.05,
        "root span {} ns exceeds the e2e wall clock {wall_ns} ns",
        root.ns
    );

    // The remote hop is grafted under the front's forward span.
    let hop = spans
        .iter()
        .find(|s| s.name.starts_with("backend:"))
        .unwrap_or_else(|| panic!("no backend hop span: {spans:?}"));
    let fwd = spans.iter().find(|s| s.id == hop.parent).expect("hop has a parent span");
    assert_eq!(fwd.name, "forward", "the hop nests under the front's forward span");

    // A fresh compile carries per-stage spans with kernel counters.
    let place = spans
        .iter()
        .find(|s| s.name == "stage:place")
        .unwrap_or_else(|| panic!("no stage:place span: {spans:?}"));
    assert!(
        place.counters.iter().any(|(k, v)| k == "place_moves_proposed" && *v > 0),
        "place span lacks kernel counters: {place:?}"
    );

    for d in &dirs {
        let _ = std::fs::remove_dir_all(d);
    }
}

fn count_of(counts: &[(&str, u64)], name: &str) -> u64 {
    counts.iter().find(|(k, _)| *k == name).map(|(_, v)| *v).unwrap_or(0)
}

#[test]
fn kernel_counters_agree_with_ground_truth_recounts() {
    use cascade::arch::params::ArchParams;
    use cascade::pnr::{build_nets, place, PlaceParams};

    // Placement: every proposed move is exactly accepted or rejected.
    let arch = ArchParams::paper();
    let app = cascade::apps::dense::gaussian(64, 64, 2);
    let nets = build_nets(&app.dfg, &arch);
    let (_placement, counts) =
        with_counters(|| place(&app.dfg, &nets, &arch, &PlaceParams::baseline(3)));
    let proposed = count_of(&counts, "place_moves_proposed");
    assert!(proposed > 0, "placement proposed no moves: {counts:?}");
    assert_eq!(
        count_of(&counts, "place_moves_accepted") + count_of(&counts, "place_moves_rejected"),
        proposed,
        "accepted + rejected must recount to proposed: {counts:?}"
    );

    // STA engine: it can never repropagate more nodes than the design has.
    let ctx = CompileCtx::paper();
    let c = compile(&app, &ctx, &PipelineConfig::compute_only(), 3).unwrap();
    let mut engine = cascade::timing::sta::StaEngine::new(&c.design);
    let (_report, counts) = with_counters(|| engine.analyze(&c.design, &ctx.graph));
    let total = count_of(&counts, "sta_nodes_total");
    assert!(total > 0, "STA saw no nodes: {counts:?}");
    assert!(
        count_of(&counts, "sta_nodes_repropagated") <= total,
        "repropagated nodes exceed the node count: {counts:?}"
    );

    // Fusion: the counters are the pass's own report, recounted.
    let mut g = cascade::apps::dense::unsharp(64, 64, 1).dfg;
    let (report, counts) = with_counters(|| cascade::dfg::fuse::fuse_chains(&mut g));
    assert!(report.chains > 0, "unsharp must have fusible chains");
    assert_eq!(count_of(&counts, "fuse_chains"), report.chains as u64, "{counts:?}");
    assert_eq!(count_of(&counts, "fuse_nodes_fused"), report.nodes_fused as u64, "{counts:?}");
    assert_eq!(
        count_of(&counts, "fuse_nodes_removed"),
        report.nodes_removed as u64,
        "{counts:?}"
    );
}

#[test]
fn instrumentation_never_perturbs_explore_reports_or_routed_outputs() {
    use std::sync::Arc;

    use cascade::explore::{report, EvalSession, ExploreSpec, Scale};

    // Explore: the same sweep with and without an attached registry (what
    // `--profile` does) renders byte-identical report bodies.
    let ctx = CompileCtx::paper();
    let spec = ExploreSpec::default()
        .with_apps(["gaussian"])
        .with_levels(["none", "compute"])
        .with_seeds([1])
        .with_fast(true)
        .with_scale(Scale::Tiny);
    let points = spec.points();

    let plain = EvalSession::new(&spec, &ctx, None, None).eval_points(&points, 2, None);
    let reg = Arc::new(cascade::obs::Registry::new());
    let mut traced_session = EvalSession::new(&spec, &ctx, None, None);
    traced_session.set_obs(reg.clone());
    let traced = traced_session.eval_points(&points, 2, None);

    let pa = report::analyze(&spec, &plain);
    let ta = report::analyze(&spec, &traced);
    assert_eq!(
        report::to_markdown(&spec, &plain, &pa),
        report::to_markdown(&spec, &traced, &ta),
        "explore.md differs with instrumentation attached"
    );
    assert_eq!(
        report::to_json(&spec, &plain, &pa).to_string_pretty(),
        report::to_json(&spec, &traced, &ta).to_string_pretty(),
        "explore.json differs with instrumentation attached"
    );
    assert!(
        reg.counter_series("compile_kernel_").iter().any(|(_, v)| *v > 0),
        "the instrumented run must actually have counted kernel work"
    );

    // Routed path: a logging front/backend pair and a logless one serve
    // byte-identical keys and bitstreams.
    let q = tiny_point();
    let mut outputs: Vec<(String, String)> = Vec::new();
    for (tag, log) in [("log", LogTarget::Default), ("nolog", LogTarget::Disabled)] {
        let bdir = tmp(&format!("ident-b-{tag}"));
        let fdir = tmp(&format!("ident-f-{tag}"));
        let _ = std::fs::remove_dir_all(&bdir);
        let _ = std::fs::remove_dir_all(&fdir);
        let mut bcfg = ServeConfig::new("127.0.0.1:0");
        bcfg.workers = 1;
        bcfg.cache_dir = bdir.clone();
        bcfg.log = log.clone();
        let Some(backend) = bind_or_skip(bcfg) else { return };
        let baddr = backend.addr().to_string();
        std::thread::scope(|s| {
            s.spawn(|| backend.run(&ctx).unwrap());
            let mut fcfg = ServeConfig::new("127.0.0.1:0");
            fcfg.workers = 1;
            fcfg.cache_dir = fdir.clone();
            fcfg.log = log.clone();
            fcfg.route = vec![baddr.clone()];
            let front = Server::bind(fcfg).expect("front binds");
            let front_addr = front.addr().to_string();
            s.spawn(|| front.run(&ctx).unwrap());

            let mut c = Client::connect(front_addr.as_str(), opts()).unwrap();
            let r = c.encode_point(&q).unwrap();
            assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true), "{r:?}");
            outputs.push((
                r.get("key").and_then(Json::as_str).unwrap().to_string(),
                r.get("bitstream").and_then(Json::as_str).unwrap().to_string(),
            ));
            c.shutdown().unwrap();
            let mut b = Client::connect(baddr.as_str(), opts()).unwrap();
            b.shutdown().unwrap();
        });
        let _ = std::fs::remove_dir_all(&bdir);
        let _ = std::fs::remove_dir_all(&fdir);
    }
    assert_eq!(outputs.len(), 2);
    assert_eq!(outputs[0].0, outputs[1].0, "routed key differs with the request log off");
    assert_eq!(
        outputs[0].1, outputs[1].1,
        "routed bitstream differs with the request log off"
    );
}
