//! Differential-equivalence harness for the op-fusion pass (`dfg::fuse`).
//!
//! Fusion changes the *mapping* of an application, never its function:
//! fused and unfused graphs must produce identical output streams on the
//! same inputs. This harness proves that property three ways:
//!
//! 1. Every registered benchmark (`apps::by_name`): dense apps run through
//!    the cycle-accurate interpreter, sparse apps through the ready-valid
//!    simulator, fused vs unfused on deterministic `util::rng` inputs.
//! 2. A property test over randomly generated legal DFGs (seeded
//!    splitmix64): fusion preserves acyclicity and structural validity,
//!    never crosses MEM / multi-fanout / sparse boundaries, preserves
//!    interpreter semantics, and `unfuse` reproduces the original graph
//!    modulo node ids.
//! 3. The compiled-artifact level: fusion on must yield strictly fewer
//!    placed nodes AND strictly fewer pipeline registers on at least three
//!    benchmarks (the PR's acceptance bar).

use std::collections::BTreeMap;

use cascade::apps;
use cascade::dfg::fuse::{fuse_chains, unfuse, FuseReport, MAX_FUSED_OPS};
use cascade::dfg::interp::Interp;
use cascade::dfg::{AluOp, Dfg, NodeId, Op, SparseOp};
use cascade::pipeline::{compile, CompileCtx, Compiled, PipelineConfig};
use cascade::sparse::sim::simulate_app;
use cascade::util::rng::Rng;

/// Deterministic per-lane input streams. Dense fabric values are
/// pixel-like; per-op overflow/edge-value semantics are covered by the
/// `dfg::interp` unit tests, this harness exercises whole applications.
fn deterministic_inputs(g: &Dfg, cycles: u64, seed: u64) -> BTreeMap<u16, Vec<i64>> {
    let mut inputs = BTreeMap::new();
    for node in &g.nodes {
        if let Op::Input { lane } = node.op {
            let mut rng = Rng::new(seed ^ (0x9e37_79b9_7f4a_7c15u64.wrapping_mul(lane as u64 + 1)));
            let stream = (0..cycles).map(|_| rng.gen_range_i64(0, 255)).collect();
            inputs.insert(lane, stream);
        }
    }
    inputs
}

/// Fuse a copy of `g`, returning the fused graph and the pass report.
fn fused_copy(g: &Dfg) -> (Dfg, FuseReport) {
    let mut fused = g.clone();
    let report = fuse_chains(&mut fused);
    (fused, report)
}

/// Total pipeline registers a compiled design spends: switchbox registers,
/// register-file delay words, FIFO stages, edge pipeline registers, and
/// the two input-register words of every pipelined PE. Fusion must lower
/// this total — fewer PEs means fewer input registers to balance.
fn pipeline_reg_total(c: &Compiled) -> u64 {
    let (sb, rf, fifos) = c.design.pipelining_resources();
    let in_regs = c.design.dfg.nodes.iter().filter(|n| n.input_regs).count() as u64;
    sb as u64 + rf + fifos + c.design.dfg.total_edge_regs() + 2 * in_regs
}

// ---------------------------------------------------------------------
// 1. Differential equivalence over every registered benchmark.
// ---------------------------------------------------------------------

#[test]
fn dense_apps_fused_and_unfused_produce_identical_outputs() {
    let mut fusible = Vec::new();
    for name in apps::APP_NAMES {
        if apps::is_sparse_name(name) {
            continue;
        }
        let app = apps::by_name_tiny(name).unwrap();
        let (fused, report) = fused_copy(&app.dfg);
        assert!(fused.validate().is_empty(), "{name}: {:?}", fused.validate());
        assert_eq!(
            app.dfg.nodes.len() - fused.nodes.len(),
            report.nodes_removed,
            "{name}: report disagrees with the graph"
        );

        let cycles = 400;
        let inputs = deterministic_inputs(&app.dfg, cycles, 0x5eed);
        let base = Interp::run(&app.dfg, &inputs, cycles);
        let alt = Interp::run(&fused, &inputs, cycles);
        assert_eq!(
            base.outputs, alt.outputs,
            "{name}: fused graph diverges from unfused"
        );

        if report.nodes_removed > 0 {
            assert!(
                fused.nodes.len() < app.dfg.nodes.len(),
                "{name}: fusible app must shrink"
            );
            fusible.push(name);
        }
    }
    // The dense suite has known single-fanout ALU chains (e.g. unsharp's
    // diff -> amp -> scale); fusion finding none would mean the pass or
    // the legality rules regressed.
    for name in ["gaussian", "unsharp", "camera", "harris"] {
        assert!(fusible.contains(&name), "{name} should have fusible chains");
    }
}

#[test]
fn sparse_apps_fused_and_unfused_produce_identical_outputs() {
    for name in apps::APP_NAMES {
        if !apps::is_sparse_name(name) {
            continue;
        }
        let app = apps::by_name(name).unwrap();
        let data = apps::sparse::data_for(name, 42);
        let (fused, _) = fused_copy(&app.dfg);
        assert!(fused.validate().is_empty(), "{name}: {:?}", fused.validate());
        let base = simulate_app(name, &app.dfg, &data);
        let alt = simulate_app(name, &fused, &data);
        assert_eq!(
            base.outputs, alt.outputs,
            "{name}: fusion changed sparse outputs"
        );
    }
}

// ---------------------------------------------------------------------
// 2. Property test over random legal DFGs.
// ---------------------------------------------------------------------

/// Ops the generator draws from. `Mul` is only ever given an immediate
/// (and a small one), so values stay well inside i64 at any chain depth —
/// `AluOp::eval` is non-wrapping and would panic on debug overflow.
const GEN_OPS: [AluOp; 10] = [
    AluOp::Add,
    AluOp::Sub,
    AluOp::Mul,
    AluOp::Min,
    AluOp::Max,
    AluOp::Shr,
    AluOp::And,
    AluOp::Or,
    AluOp::Xor,
    AluOp::Abs,
];

/// Generate a random legal DFG: a few input lanes, a soup of ALU nodes
/// (immediate or two-operand), occasional MEM-kind delay taps, occasional
/// sparse value nodes, and outputs terminating every dangling producer.
/// Returns the graph and whether it contains sparse nodes (those graphs
/// skip the interpreter check — `Interp` rejects ready-valid nodes).
fn random_legal_dfg(seed: u64) -> (Dfg, bool) {
    let mut rng = Rng::new(seed);
    let mut g = Dfg::new();
    let mut values: Vec<NodeId> = Vec::new();
    let mut has_sparse = false;

    let lanes = 1 + rng.gen_range(3);
    for lane in 0..lanes {
        values.push(g.add_node(Op::Input { lane: lane as u16 }, format!("in{lane}")));
    }

    let ops = 8 + rng.gen_range(11);
    for k in 0..ops {
        let roll = rng.gen_f64();
        if roll < 0.12 {
            // MEM-kind boundary: a delay tap fusion must never cross.
            let src = *rng.choose(&values);
            let d = g.add_node(
                Op::Delay { cycles: 1 + rng.gen_range(12) as u32, pipelined: false },
                format!("d{k}"),
            );
            g.connect(src, d, 0);
            values.push(d);
            continue;
        }
        if roll < 0.2 {
            // Sparse boundary: a ready-valid ALU fusion must never absorb.
            let src = *rng.choose(&values);
            let s = g.add_node(Op::Sparse(SparseOp::SpAlu(AluOp::Add)), format!("s{k}"));
            g.connect(src, s, 0);
            values.push(s);
            has_sparse = true;
            continue;
        }
        let op = *rng.choose(&GEN_OPS);
        let immediate = matches!(op, AluOp::Mul) || rng.gen_bool(0.5);
        let const_b = if matches!(op, AluOp::Abs) {
            None
        } else if immediate {
            Some(rng.gen_range_i64(0, 3))
        } else {
            None
        };
        let a = *rng.choose(&values);
        let n = g.add_node(Op::Alu { op, const_b }, format!("n{k}"));
        g.connect(a, n, 0);
        if const_b.is_none() && !matches!(op, AluOp::Abs) {
            let b = *rng.choose(&values);
            g.connect(b, n, 1);
        }
        values.push(n);
    }

    // Terminate every dangling producer so validate() passes and the
    // interpreter observes every chain end.
    let mut lane = 0u16;
    for i in 0..g.nodes.len() as NodeId {
        let consumed = g.edges.iter().any(|e| e.src == i);
        if !consumed && !matches!(g.node(i).op, Op::Output { .. }) {
            let o = g.add_node(Op::Output { lane, decimate: 1 }, format!("o{lane}"));
            g.connect(i, o, 0);
            lane += 1;
        }
    }
    (g, has_sparse)
}

/// Structural key invariant under node renumbering: node signatures plus
/// name-addressed edge signatures (names are unique in generated graphs).
fn shape_key(g: &Dfg) -> (Vec<String>, Vec<String>) {
    let mut nodes: Vec<String> = g
        .nodes
        .iter()
        .map(|n| format!("{}:{:?}:{}", n.name, n.op, n.input_regs))
        .collect();
    nodes.sort();
    let mut edges: Vec<String> = g
        .edges
        .iter()
        .map(|e| {
            format!(
                "{}->{}:{}:{:?}:{}:{}",
                g.node(e.src).name,
                g.node(e.dst).name,
                e.dst_port,
                e.layer,
                e.regs,
                e.fifos
            )
        })
        .collect();
    edges.sort();
    (nodes, edges)
}

fn count<F: Fn(&Op) -> bool>(g: &Dfg, f: F) -> usize {
    g.nodes.iter().filter(|n| f(&n.op)).count()
}

#[test]
fn property_fusion_is_legal_semantics_preserving_and_invertible() {
    let mut graphs_with_fusion = 0;
    for seed in 0..60u64 {
        let (orig, has_sparse) = random_legal_dfg(0x5eed_0000 + seed);
        assert!(orig.validate().is_empty(), "seed {seed}: generator broke: {:?}", orig.validate());

        let (fused, report) = fused_copy(&orig);

        // Structural validity and acyclicity (topo_order panics on cycles).
        assert!(fused.validate().is_empty(), "seed {seed}: {:?}", fused.validate());
        assert_eq!(fused.topo_order().len(), fused.nodes.len());

        // Compound shape: 2..=MAX_FUSED_OPS members, never Mux/Mac.
        for n in &fused.nodes {
            if let Op::Fused { ops } = &n.op {
                assert!(
                    (2..=MAX_FUSED_OPS).contains(&ops.len()),
                    "seed {seed}: compound of {} steps",
                    ops.len()
                );
                assert!(
                    !ops.iter().any(|s| matches!(s.op, AluOp::Mux | AluOp::Mac)),
                    "seed {seed}: Mux/Mac inside a compound"
                );
            }
        }

        // MEM and sparse nodes are boundaries: never absorbed.
        assert_eq!(
            count(&orig, |o| matches!(o, Op::Delay { .. })),
            count(&fused, |o| matches!(o, Op::Delay { .. })),
            "seed {seed}: fusion crossed a MEM node"
        );
        assert_eq!(
            count(&orig, |o| matches!(o, Op::Sparse(_))),
            count(&fused, |o| matches!(o, Op::Sparse(_))),
            "seed {seed}: fusion absorbed a sparse node"
        );

        // The report matches the graph delta.
        assert_eq!(orig.nodes.len() - fused.nodes.len(), report.nodes_removed);

        // Fusion is idempotent: a second pass finds nothing left to fuse.
        let (refused, second) = fused_copy(&fused);
        assert_eq!(second, FuseReport::default(), "seed {seed}: second pass fused more");
        assert_eq!(refused.nodes.len(), fused.nodes.len());

        // Semantics: identical output streams cycle-for-cycle.
        if !has_sparse {
            let cycles = 64;
            let mut inputs = BTreeMap::new();
            let mut irng = Rng::new(0xfeed ^ seed);
            for node in &orig.nodes {
                if let Op::Input { lane } = node.op {
                    let stream = (0..cycles).map(|_| irng.gen_range_i64(-255, 255)).collect();
                    inputs.insert(lane, stream);
                }
            }
            let a = Interp::run(&orig, &inputs, cycles);
            let b = Interp::run(&fused, &inputs, cycles);
            assert_eq!(a.outputs, b.outputs, "seed {seed}: fused semantics diverge");
        }

        // Un-fusing reproduces the original modulo node ids.
        assert_eq!(shape_key(&orig), shape_key(&unfuse(&fused)), "seed {seed}: unfuse mismatch");

        if report.nodes_removed > 0 {
            graphs_with_fusion += 1;
        }
    }
    // The generator must actually exercise the pass, not vacuously hold.
    assert!(
        graphs_with_fusion >= 20,
        "only {graphs_with_fusion}/60 random graphs had fusible chains"
    );
}

// ---------------------------------------------------------------------
// 3. Compiled-artifact acceptance: fusion saves real resources.
// ---------------------------------------------------------------------

#[test]
fn fusion_strictly_reduces_nodes_and_registers_on_at_least_three_apps() {
    let ctx = CompileCtx::paper();
    // Measured at the `placement` level: every register source here
    // (compute pipelining, branch delay matching, the register-file
    // transform) is a deterministic graph transform, so the comparison is
    // exact. Post-PnR adds switchbox registers that depend on the annealed
    // placement, which would add noise without changing the conclusion.
    let unfused_cfg = PipelineConfig::with_placement();
    let fused_cfg = PipelineConfig { fusion: true, ..PipelineConfig::with_placement() };

    let mut improved = Vec::new();
    for name in apps::APP_NAMES {
        if apps::is_sparse_name(name) {
            continue;
        }
        let app = apps::by_name_tiny(name).unwrap();
        let base = compile(&app, &ctx, &unfused_cfg, 3).unwrap();
        let fused = compile(&app, &ctx, &fused_cfg, 3).unwrap();

        let nodes_down = fused.design.dfg.nodes.len() < base.design.dfg.nodes.len();
        let regs_down = pipeline_reg_total(&fused) < pipeline_reg_total(&base);
        if nodes_down && regs_down {
            improved.push(name);
        }
    }
    assert!(
        improved.len() >= 3,
        "fusion must strictly reduce placed nodes AND pipeline registers \
         on >=3 apps; got {improved:?}"
    );
}
