//! STA hot-path benchmarks: the analysis runs once per post-PnR pipelining
//! iteration, so its latency bounds compile time. Kernels live in
//! `cascade::benchsuite` so `cascade bench --suite sta` runs the same
//! suite without a bench build.

use cascade::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new("sta");
    cascade::benchsuite::run_sta(&mut b);
    b.finish();
}
