//! STA hot-path benchmarks: the analysis runs once per post-PnR pipelining
//! iteration, so its latency bounds compile time.

use cascade::pipeline::{compile, CompileCtx, PipelineConfig};
use cascade::timing::sta::analyze;
use cascade::util::bench::Bencher;

fn main() {
    let ctx = CompileCtx::paper();
    let mut b = Bencher::new("sta");

    let gauss = compile(
        &cascade::apps::dense::gaussian(6400, 4800, 16),
        &ctx,
        &PipelineConfig::compute_only(),
        3,
    )
    .unwrap();
    b.bench("analyze/gaussian_u16", || analyze(&gauss.design, &ctx.graph).period_ps);

    let harris = compile(
        &cascade::apps::dense::harris(1530, 2554, 4),
        &ctx,
        &PipelineConfig::compute_only(),
        3,
    )
    .unwrap();
    b.bench("analyze/harris_u4", || analyze(&harris.design, &ctx.graph).period_ps);

    let sp = compile(
        &cascade::apps::sparse::mat_elemmul(128, 128, 0.1),
        &ctx,
        &PipelineConfig::compute_only(),
        3,
    )
    .unwrap();
    b.bench("analyze/sparse_elemmul", || analyze(&sp.design, &ctx.graph).period_ps);

    b.finish();
}
