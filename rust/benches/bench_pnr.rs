//! Place-and-route benchmarks: SA placement and PathFinder routing on the
//! paper-scale array (the compile-time hot paths).

use cascade::arch::params::ArchParams;
use cascade::pnr::{build_nets, place, route, PlaceParams, RouteParams};
use cascade::util::bench::Bencher;

fn main() {
    let ctx = cascade::pipeline::CompileCtx::paper();
    let arch = ArchParams::paper();
    let mut b = Bencher::new("pnr");

    let app = cascade::apps::dense::gaussian(6400, 4800, 16);
    let nets = build_nets(&app.dfg, &arch);
    b.bench("place/gaussian_u16", || {
        place(&app.dfg, &nets, &arch, &PlaceParams::baseline(3)).cost
    });
    b.bench("place/gaussian_u16_alpha", || {
        place(&app.dfg, &nets, &arch, &PlaceParams::cascade(3)).cost
    });

    let placement = place(&app.dfg, &nets, &arch, &PlaceParams::baseline(3));
    b.bench("route/gaussian_u16", || {
        route(&app.dfg, &nets, &placement, &arch, &ctx.graph, &RouteParams::default())
            .unwrap()
            .len()
    });

    let harris = cascade::apps::dense::harris(1530, 2554, 4);
    let hnets = build_nets(&harris.dfg, &arch);
    b.bench("place/harris_u4", || {
        place(&harris.dfg, &hnets, &arch, &PlaceParams::baseline(5)).cost
    });

    b.finish();
}
