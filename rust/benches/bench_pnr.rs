//! Place-and-route benchmarks: SA placement and PathFinder routing on the
//! paper-scale array (the compile-time hot paths). Kernels live in
//! `cascade::benchsuite` so `cascade bench --suite pnr` runs the same
//! suite without a bench build.

use cascade::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new("pnr");
    cascade::benchsuite::run_pnr(&mut b);
    b.finish();
}
