//! End-to-end table regeneration benchmarks: one measurement per paper
//! table/figure pipeline (compile + pipelining + STA for a representative
//! app of each experiment). These are the `cargo bench` counterparts of
//! `cascade exp <id>`; run the CLI for the full tables. Kernels live in
//! `cascade::benchsuite` so `cascade bench --suite tables` runs the same
//! suite without a bench build.

use cascade::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new("tables");
    cascade::benchsuite::run_tables(&mut b);
    b.finish();
}
