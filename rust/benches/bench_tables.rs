//! End-to-end table regeneration benchmarks: one measurement per paper
//! table/figure pipeline (compile + pipelining + STA for a representative
//! app of each experiment). These are the `cargo bench` counterparts of
//! `cascade exp <id>`; run the CLI for the full tables.

use cascade::pipeline::{compile, CompileCtx, PipelineConfig};
use cascade::timing::gatelevel::{gate_level_period_ps, GateLevelParams};
use cascade::util::bench::Bencher;

fn main() {
    let ctx = CompileCtx::paper();
    let mut b = Bencher::new("tables");

    // Fig. 6 pipeline: compile + STA + gate-level surrogate.
    b.bench("fig6/gaussian_point", || {
        let c = compile(
            &cascade::apps::dense::gaussian(64, 64, 2),
            &ctx,
            &PipelineConfig::compute_only(),
            3,
        )
        .unwrap();
        gate_level_period_ps(&c.design, &ctx.graph, &GateLevelParams::default())
    });

    // Table I pipeline: full Cascade compile of one app.
    b.bench("table1/unsharp_full", || {
        compile(
            &cascade::apps::dense::unsharp(1536, 2560, 4),
            &ctx,
            &PipelineConfig::with_postpnr(),
            3,
        )
        .unwrap()
        .fmax_mhz()
    });

    // Table II pipeline: sparse compile + ready-valid simulation.
    b.bench("table2/vec_elemadd_all", || {
        let app = cascade::apps::sparse::vec_elemadd(4096, 0.25);
        let cfg = PipelineConfig::sparse_ladder().pop().unwrap().1;
        let c = compile(&app, &ctx, &cfg, 11).unwrap();
        let data = cascade::apps::sparse::data_for("vec_elemadd", 42);
        cascade::sparse::sim::simulate_app("vec_elemadd", &c.design.dfg, &data).cycles
    });

    b.finish();
}
