//! Simulator benchmarks: fabric cycle simulation and the sparse
//! ready-valid actor simulation. Kernels live in `cascade::benchsuite`
//! so `cascade bench --suite sim` runs the same suite without a bench
//! build.

use cascade::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new("sim");
    cascade::benchsuite::run_sim(&mut b);
    b.finish();
}
