//! Simulator benchmarks: fabric cycle simulation and the sparse
//! ready-valid actor simulation.

use std::collections::BTreeMap;

use cascade::pipeline::{compile, CompileCtx, PipelineConfig};
use cascade::sim::dense::FabricSim;
use cascade::sparse::sim::simulate_app;
use cascade::util::bench::Bencher;

fn main() {
    let ctx = CompileCtx::paper();
    let mut b = Bencher::new("sim");

    let c = compile(
        &cascade::apps::dense::gaussian(64, 64, 1),
        &ctx,
        &PipelineConfig::with_postpnr(),
        3,
    )
    .unwrap();
    let mut ins = BTreeMap::new();
    ins.insert(0u16, (0..4096).map(|x| (x * 7 + 5) % 31).collect::<Vec<i64>>());
    b.bench("fabric/gaussian_64x64_frame", || {
        FabricSim::run(&c.design, &ins, 4096).outputs.len()
    });

    let interp_g = c.design.dfg.clone();
    b.bench("interp/gaussian_64x64_frame", || {
        cascade::dfg::interp::Interp::run(&interp_g, &ins, 4096).outputs.len()
    });

    let app = cascade::apps::sparse::mat_elemmul(128, 128, 0.1);
    let data = cascade::apps::sparse::data_for("mat_elemmul", 42);
    b.bench("sparse/mat_elemmul_128", || {
        simulate_app("mat_elemmul", &app.dfg, &data).cycles
    });

    let tt = cascade::apps::sparse::tensor_ttv(48, 48, 48, 0.05);
    let tdata = cascade::apps::sparse::data_for("ttv", 42);
    b.bench("sparse/ttv_48", || simulate_app("ttv", &tt.dfg, &tdata).cycles);

    b.finish();
}
