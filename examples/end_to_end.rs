//! End-to-end driver: proves all layers compose on a real small workload.
//!
//! For each dense benchmark (64x64 frame):
//!   1. compile with the full Cascade pipeline (map -> schedule -> place ->
//!      route -> pipelining passes -> reschedule);
//!   2. encode the bitstream and verify the configuration round-trip;
//!   3. run the cycle-accurate *fabric* simulator on the routed, registered
//!      design;
//!   4. execute the AOT-compiled JAX/Pallas golden model through PJRT
//!      (Layer 1+2, built once by `make artifacts`) on the same input;
//!   5. check every output sample matches (up to the pipeline latency the
//!      schedule reports);
//!   6. report frequency / runtime / power / EDP, paper-style.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use std::collections::BTreeMap;

use cascade::pipeline::{compile, CompileCtx, PipelineConfig};
use cascade::runtime::GoldenRuntime;
use cascade::sim::dense::FabricSim;

fn main() {
    let mut rt = match GoldenRuntime::from_repo_root() {
        Ok(rt) if rt.has_artifact("gaussian") => rt,
        _ => {
            eprintln!("artifacts missing — run `make artifacts` first");
            std::process::exit(1);
        }
    };

    println!("building the 32x16 CGRA model + timing library...");
    let ctx = CompileCtx::paper();
    let n = 4096usize; // 64x64 frame as a flat stream
    let input: Vec<i32> = (0..n as i32).map(|x| (x * 7 + 5) % 31).collect();

    let mut failures = 0;
    for (name, app) in [
        ("gaussian", cascade::apps::dense::gaussian(64, 64, 1)),
        ("unsharp", cascade::apps::dense::unsharp(64, 64, 1)),
        ("camera", cascade::apps::dense::camera(64, 64, 1)),
        ("harris", cascade::apps::dense::harris(64, 64, 1)),
    ] {
        let cfg = PipelineConfig::with_postpnr();
        let c = compile(&app, &ctx, &cfg, 3).expect("compile");
        c.design.registers_consistent().expect("register realization");

        // Bitstream round-trip.
        let bs = cascade::sim::encode::encode(&c.design, &c.schedule, &ctx.graph);
        let problems = cascade::sim::encode::verify_roundtrip(&c.design, &bs, &ctx.graph);
        assert!(problems.is_empty(), "{name}: bitstream roundtrip: {problems:?}");

        // Fabric simulation.
        let mut ins = BTreeMap::new();
        ins.insert(0u16, input.iter().map(|&v| v as i64).collect::<Vec<i64>>());
        let run = FabricSim::run(&c.design, &ins, n as u64);
        let fabric = &run.outputs[&0];

        // PJRT golden model.
        let golden = rt.run_i32(name, &input).expect("golden model");

        // The fabric output is the golden stream delayed by the pipeline
        // latency the schedule reports (algorithmic delays are inside the
        // golden model; added pipelining is not).
        let lat = cascade::pipeline::bdm::added_arrival_cycles(&c.design.dfg);
        let out_node = c
            .design
            .dfg
            .nodes
            .iter()
            .position(|nd| matches!(nd.op, cascade::dfg::ir::Op::Output { .. }))
            .unwrap();
        let shift = lat[out_node] as usize;
        let mut mismatches = 0;
        for t in 0..n - shift {
            if fabric[t + shift] != golden[t] as i64 {
                mismatches += 1;
            }
        }
        let p = cascade::sim::power::estimate(
            &c.design,
            c.fmax_mhz(),
            &cascade::sim::power::EnergyModel::default(),
        );
        let status = if mismatches == 0 { "OK " } else { "FAIL" };
        if mismatches > 0 {
            failures += 1;
        }
        println!(
            "[{status}] {name:<9} fabric==golden ({} samples, latency {shift}) | \
             fmax {:>4.0} MHz | {:>7} cycles/frame | {:>4.0} mW | EDP {:.4}",
            n - shift,
            c.fmax_mhz(),
            c.schedule.total_cycles,
            p.total_mw(),
            p.edp(c.runtime_ms())
        );
    }

    // ResNet layer: multi-input golden (2-D input).
    {
        let app = cascade::apps::dense::resnet_small();
        let c = compile(&app, &ctx, &PipelineConfig::with_postpnr(), 9).expect("compile resnet");
        let taps = 4usize;
        let tmul = 18usize;
        let n_out = 64usize;
        let cycles = n_out * tmul;
        let mut flat = Vec::new();
        let mut ins = BTreeMap::new();
        for t in 0..taps {
            let stream: Vec<i32> =
                (0..cycles as i32).map(|k| (k + t as i32) % 7 - 3).collect();
            flat.extend(stream.iter().copied());
            ins.insert(t as u16, stream.iter().map(|&v| v as i64).collect::<Vec<i64>>());
        }
        let golden = rt.run_i32_2d("resnet", &flat, taps, cycles).expect("resnet golden");
        // Golden y[l, o]; fabric lane l decimated outputs. Compare via the
        // logical interpreter (fabric==interp is covered by unit tests) at
        // accumulator boundaries.
        let run = cascade::dfg::interp::Interp::run(&c.design.dfg, &ins, (cycles + 64) as u64);
        let arr = cascade::pipeline::bdm::added_arrival_cycles(&c.design.dfg);
        let mut ok = true;
        for l in 0..2u16 {
            let out_node = c
                .design
                .dfg
                .nodes
                .iter()
                .position(|nd| matches!(nd.op, cascade::dfg::ir::Op::Output { lane, .. } if lane == l))
                .unwrap();
            let lat = arr[out_node] as usize;
            let stream = &run.outputs[&l];
            for o in 0..n_out {
                // Window o's total reaches the accumulator output register
                // at (o+1)*T (schedule-aligned, §V-F) plus the pipelining
                // latency to the IO.
                let t = (o + 1) * tmul + lat;
                let expect = golden[l as usize * n_out + o] as i64;
                if stream.get(t).copied() != Some(expect) {
                    ok = false;
                }
            }
        }
        if !ok {
            failures += 1;
        }
        println!(
            "[{}] resnet    GEMM golden vs accumulator lanes | fmax {:>4.0} MHz",
            if ok { "OK " } else { "FAIL" },
            c.fmax_mhz()
        );
    }

    if failures > 0 {
        eprintln!("{failures} end-to-end check(s) failed");
        std::process::exit(1);
    }
    println!("\nall end-to-end checks passed: compiler -> bitstream -> fabric sim == JAX/Pallas golden via PJRT");
}
