//! Sweep every pipelining technique over a dense app and print the
//! incremental effect (a single-app Fig. 7).
//!
//! `cargo run --release --example pipelining_sweep [-- app]`

use cascade::pipeline::{compile, CompileCtx, PipelineConfig};

fn main() {
    let which = std::env::args().nth(1).unwrap_or_else(|| "harris".to_string());
    let app = match which.as_str() {
        "gaussian" => cascade::apps::dense::gaussian(6400, 4800, 16),
        "unsharp" => cascade::apps::dense::unsharp(1536, 2560, 4),
        "camera" => cascade::apps::dense::camera(2560, 1920, 4),
        "harris" => cascade::apps::dense::harris(1530, 2554, 4),
        "resnet" => cascade::apps::dense::resnet_conv5x(),
        other => {
            eprintln!("unknown app {other}");
            std::process::exit(2);
        }
    };
    println!("building context...");
    let ctx = CompileCtx::paper();
    println!(
        "{:<14} {:>10} {:>10} {:>12} {:>10} {:>8}",
        "technique", "crit (ns)", "fmax MHz", "runtime ms", "SB regs", "speedup"
    );
    let mut base = None;
    for (name, cfg) in PipelineConfig::ladder() {
        let c = compile(&app, &ctx, &cfg, 3).expect("compile");
        let b = *base.get_or_insert(c.runtime_ms());
        let (sb, _, _) = c.design.pipelining_resources();
        println!(
            "{:<14} {:>10.2} {:>10.0} {:>12.3} {:>10} {:>7.2}x",
            name,
            c.sta.period_ps / 1000.0,
            c.fmax_mhz(),
            c.runtime_ms(),
            sb,
            b / c.runtime_ms()
        );
    }
}
