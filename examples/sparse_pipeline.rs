//! Sparse workload demo: compile the four Table II kernels at each sparse
//! pipelining level, run the ready-valid fabric simulation against the
//! direct golden computation, and report frequency/runtime/EDP.
//!
//! `cargo run --release --example sparse_pipeline`

use cascade::pipeline::{compile, CompileCtx, PipelineConfig};
use cascade::sparse::{golden, sim::simulate_app};

fn main() {
    println!("building context...");
    let ctx = CompileCtx::paper();
    for app in cascade::apps::paper_sparse_suite() {
        let data = cascade::apps::sparse::data_for(app.name, 42);
        let expect = golden::golden(app.name, &data);
        println!("\n== {} ({} outputs expected)", app.name, expect.len());
        for (name, cfg) in PipelineConfig::sparse_ladder() {
            let c = compile(&app, &ctx, &cfg, 11).expect("compile");
            let run = simulate_app(app.name, &c.design.dfg, &data);
            assert_eq!(run.outputs, expect, "{}: functional mismatch", app.name);
            let p = cascade::sim::power::estimate(
                &c.design,
                c.fmax_mhz(),
                &cascade::sim::power::EnergyModel::default(),
            );
            let runtime_us = run.cycles as f64 / c.fmax_mhz();
            println!(
                "  {:<18} crit {:>5.2} ns | fmax {:>4.0} MHz | {:>7} cycles | {:>7.2} us | {:>4.0} mW (outputs verified)",
                name,
                c.sta.period_ps / 1000.0,
                c.fmax_mhz(),
                run.cycles,
                runtime_us,
                p.total_mw()
            );
        }
    }
    println!("\nall sparse runs produced golden-identical outputs under backpressure");
}
