//! Quickstart: compile one application at two pipelining levels and watch
//! the critical path collapse.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use cascade::pipeline::{compile, CompileCtx, PipelineConfig};

fn main() {
    // The paper's evaluation array: 32x16 tiles, 384 PE + 128 MEM, with a
    // generated timing model (component worst-case delays + clock skew).
    println!("building the 32x16 CGRA model + timing library...");
    let ctx = CompileCtx::paper();

    // A 3x3 Gaussian blur over a 6400x4800 frame, unrolled 16x.
    let app = cascade::apps::dense::gaussian(6400, 4800, 16);
    println!(
        "app: {} ({} DFG nodes, {} edges)\n",
        app.name,
        app.dfg.nodes.len(),
        app.dfg.edges.len()
    );

    for (name, cfg) in [
        ("baseline compiler (no pipelining)", PipelineConfig::none()),
        ("Cascade (all techniques)", PipelineConfig::full()),
    ] {
        let c = compile(&app, &ctx, &cfg, 3).expect("compile");
        let (sb, rf, fifos) = c.design.pipelining_resources();
        println!("== {name}");
        println!(
            "   critical path {:.2} ns -> fmax {:.0} MHz",
            c.sta.period_ps / 1000.0,
            c.fmax_mhz()
        );
        println!("   runtime {:.2} ms/frame", c.runtime_ms());
        println!("   pipelining resources: {sb} SB regs, {rf} RF words, {fifos} FIFOs\n");
    }
}
