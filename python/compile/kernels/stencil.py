"""Layer-1 Pallas kernel: weighted tap-sum (stream stencil).

The CGRA executes stencils as weighted sums over delayed taps of a
flattened row-major pixel stream (line buffers + register taps). The golden
model mirrors that exactly: `out[t] = sum_k w_k * x[t - d_k]` with
zero-filled history. The shift is materialized in the L2 JAX graph (cheap
gathers XLA fuses away); this kernel is the compute hot-spot — the weighted
reduction over the tap axis — written in Pallas.

TPU mapping (DESIGN.md §Hardware-Adaptation): the stream is tiled into
VMEM-resident blocks on the last axis; the tap axis stays resident per
block; the multiply-accumulate vectorizes on the VPU lanes (this is an
elementwise-reduce kernel — the MXU kernel of this suite is `matmul.py`).
`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; structure, not wallclock, is what carries to real TPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size along the stream axis. 512 int32 x (taps<=16) fits VMEM with
# plenty of margin and divides every stream length we AOT (4096).
BLOCK = 512


def _tap_sum_kernel(x_ref, w_ref, o_ref):
    """x_ref: (T, B) pre-shifted taps; w_ref: (T, 1); o_ref: (B,)."""
    taps = x_ref[...]              # (T, B) block in VMEM
    w = w_ref[...]                 # (T, 1)
    o_ref[...] = jnp.sum(taps * w, axis=0)


@functools.partial(jax.jit, static_argnames=("block",))
def tap_weighted_sum(taps, weights, block=BLOCK):
    """Weighted sum over the tap axis.

    taps: int32[T, N] — pre-shifted input copies (tap k delayed by d_k).
    weights: int32[T] — stencil weights.
    returns int32[N].
    """
    t, n = taps.shape
    assert n % block == 0, f"stream length {n} not a multiple of {block}"
    w2 = weights.reshape(t, 1).astype(jnp.int32)
    return pl.pallas_call(
        _tap_sum_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((t, block), lambda i: (0, i)),
            pl.BlockSpec((t, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        interpret=True,
    )(taps.astype(jnp.int32), w2)


def shift_stream(x, delay):
    """x delayed by `delay` samples with zero fill (hardware reset state)."""
    if delay == 0:
        return x
    return jnp.concatenate([jnp.zeros((delay,), x.dtype), x[:-delay]])


def stream_stencil(x, width, kernel):
    """CGRA-semantics stencil: taps at delays r*width+c, kernel[r][c] weights.

    Matches `cascade::dfg::build::stencil` bit-for-bit (including the
    zero-filled warmup region and the row-wrap behaviour of the flattened
    stream).
    """
    delays = []
    weights = []
    k = len(kernel)
    for r in range(k):
        for c in range(len(kernel[r])):
            wv = kernel[r][c]
            if wv == 0:
                continue
            delays.append(r * width + c)
            weights.append(wv)
    taps = jnp.stack([shift_stream(x, d) for d in delays])
    return tap_weighted_sum(taps, jnp.array(weights, dtype=jnp.int32))
