"""Pure-jnp/numpy oracles for the Pallas kernels (the L1 correctness
signal: pytest asserts kernel == ref across shape/weight sweeps)."""

import jax.numpy as jnp
import numpy as np


def tap_weighted_sum_ref(taps, weights):
    """Reference for kernels.stencil.tap_weighted_sum."""
    return jnp.sum(
        taps.astype(jnp.int32) * weights.astype(jnp.int32)[:, None], axis=0
    )


def matmul_ref(w, x):
    """Reference for kernels.matmul.matmul_tiled."""
    return jnp.dot(w.astype(jnp.int32), x.astype(jnp.int32))


def stream_stencil_ref(x, width, kernel):
    """Numpy reference of the CGRA stream-stencil semantics."""
    x = np.asarray(x, dtype=np.int64)
    out = np.zeros_like(x)
    for r, row in enumerate(kernel):
        for c, w in enumerate(row):
            if w == 0:
                continue
            d = r * width + c
            shifted = np.concatenate([np.zeros(d, dtype=np.int64), x[: len(x) - d]])
            out += w * shifted
    return out.astype(np.int32)
