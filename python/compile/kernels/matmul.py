"""Layer-1 Pallas kernel: tiled integer matmul (the ResNet conv-as-GEMM).

The CGRA maps the ResNet conv5_x layer weight-stationary: `lanes` output
channels x `taps*time_mult` reduction, which is exactly a GEMM
`Y[l, o] = sum_j W[l, j] * X[j, o]`. On a real TPU this is the MXU kernel:
tiles of (TM, TK) x (TK, TN) staged HBM->VMEM via BlockSpec, systolic
matmul per tile, accumulation across the K grid axis. `interpret=True` for
CPU-PJRT execution (see DESIGN.md §Hardware-Adaptation for the
VMEM/MXU-utilization estimate of the TPU variant).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(w_ref, x_ref, o_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        w_ref[...], x_ref[...], preferred_element_type=jnp.int32
    )


@functools.partial(jax.jit, static_argnames=("tm", "tk", "tn"))
def matmul_tiled(w, x, tm=8, tk=8, tn=16):
    """Y = W @ X over int32 with (tm, tk, tn) tiling.

    w: int32[M, K], x: int32[K, N] with M % tm == K % tk == N % tn == 0.
    """
    m, k = w.shape
    k2, n = x.shape
    assert k == k2
    assert m % tm == 0 and k % tk == 0 and n % tn == 0, (m, k, n)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        grid=(m // tm, n // tn, k // tk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        interpret=True,
    )(w.astype(jnp.int32), x.astype(jnp.int32))
