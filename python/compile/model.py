"""Layer-2 JAX golden models of the five dense benchmark applications.

Each function reproduces, bit-for-bit, the stream semantics of the
corresponding CGRA application in `rust/src/apps/dense.rs` (flattened
row-major pixel streams, zero-filled warmup, arithmetic shifts), composing
the Layer-1 Pallas kernels. `aot.py` lowers these once to HLO text; the
Rust runtime executes them through PJRT as the cross-language golden
reference for the fabric simulator.

All arithmetic is int32 (the fabric is a 16-bit word machine exercised with
small test values; int32 avoids overflow in the golden path exactly like
the i64 interpreter does on the Rust side).
"""

import jax.numpy as jnp

from .kernels.matmul import matmul_tiled
from .kernels.stencil import shift_stream, stream_stencil

GAUSS_KERNEL = ((1, 2, 1), (2, 4, 2), (1, 2, 1))


def gaussian(x, width=64):
    """3x3 Gaussian blur: `stencil >> 4` (one unroll lane)."""
    return jnp.right_shift(stream_stencil(x, width, GAUSS_KERNEL), 4)


def unsharp(x, width=64):
    """Unsharp masking (matches apps::dense::unsharp lane semantics)."""
    window = 2 * width + 2
    blur = jnp.right_shift(stream_stencil(x, width, GAUSS_KERNEL), 4)
    delayed = shift_stream(x, window)
    diff = delayed - blur
    sharp = jnp.right_shift(diff * 3, 2)
    return delayed + sharp


def camera(x, width=64):
    """Camera pipeline: black level, demosaic-lite, color mix, gamma."""
    bl = jnp.maximum(x - 16, 0)
    dem = jnp.right_shift(
        stream_stencil(bl, width, ((0, 1, 0), (1, 4, 1), (0, 1, 0))), 3
    )
    mix = jnp.right_shift(stream_stencil(dem, width, ((5, 2, 1),)), 3)
    lo = jnp.left_shift(mix, 1)
    hi = jnp.right_shift(mix, 1) + 96
    return jnp.where(mix >= 64, hi, lo)


def harris(x, width=64):
    """Harris corner response (matches apps::dense::harris)."""
    sx = stream_stencil(x, width, ((-1, 0, 1), (-2, 0, 2), (-1, 0, 1)))
    sy = stream_stencil(x, width, ((-1, -2, -1), (0, 0, 0), (1, 2, 1)))
    ixx = sx * sx
    iyy = sy * sy
    ixy = sx * sy
    ones = ((1, 1, 1), (1, 1, 1), (1, 1, 1))
    sxx = stream_stencil(ixx, width, ones)
    syy = stream_stencil(iyy, width, ones)
    sxy = stream_stencil(ixy, width, ones)
    det = sxx * syy - sxy * sxy
    tr = sxx + syy
    return det - jnp.right_shift(tr * tr, 4)


def resnet_weights(lanes, taps, time_mult):
    """The deterministic weight pattern of apps::dense::resnet_conv
    (w[l][t][k] = ((l*7 + t*3 + k) % 5) - 2), flattened to (lanes,
    taps*time_mult) for the GEMM formulation."""
    l = jnp.arange(lanes)[:, None, None]
    t = jnp.arange(taps)[None, :, None]
    k = jnp.arange(time_mult)[None, None, :]
    w = (l * 7 + t * 3 + k) % 5 - 2
    return w.reshape(lanes, taps * time_mult).astype(jnp.int32)


def resnet(x, lanes=2, taps=4, time_mult=18):
    """ResNet conv layer as GEMM: x int32[taps, n_out*time_mult] input
    streams -> y int32[lanes, n_out].

    y[l, o] = sum_{t, c} x[t, o*T + c] * w[l, t, c]  (the accumulator
    semantics of the CGRA mapping, one output per `time_mult` cycles)."""
    _, total = x.shape
    n_out = total // time_mult
    # Xwin[(t, c), o] = x[t, o*T + c]
    xw = x.reshape(taps, n_out, time_mult).transpose(0, 2, 1).reshape(
        taps * time_mult, n_out
    )
    w = resnet_weights(lanes, taps, time_mult)
    return matmul_tiled(w, xw, tm=min(8, lanes), tk=8, tn=16)


#: name -> (fn, example input shape) for AOT lowering. Streams are 64x64
#: frames (4096 samples); resnet is the test-scale layer.
MODELS = {
    "gaussian": (gaussian, (4096,)),
    "unsharp": (unsharp, (4096,)),
    "camera": (camera, (4096,)),
    "harris": (harris, (4096,)),
    "resnet": (resnet, (4, 64 * 18)),
}
