"""AOT lowering: JAX golden models -> HLO *text* artifacts.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax >=
0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. Lowered with `return_tuple=True`;
the Rust side unwraps with `to_tuple1()`.

Usage: python -m compile.aot --outdir ../artifacts [--only name]
Python runs only here, at build time; the Rust binary never invokes it.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import MODELS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name: str) -> str:
    fn, shape = MODELS[name]
    spec = jax.ShapeDtypeStruct(shape, jnp.int32)
    wrapped = lambda x: (fn(x),)  # noqa: E731 — 1-tuple for to_tuple1()
    return to_hlo_text(jax.jit(wrapped).lower(spec))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single model")
    # Back-compat with `make artifacts` single-file invocation.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    outdir = args.outdir
    if args.out:
        outdir = os.path.dirname(args.out) or "."
    os.makedirs(outdir, exist_ok=True)

    names = [args.only] if args.only else list(MODELS)
    for name in names:
        text = lower_model(name)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>8} chars to {path}")
    # Marker file so `make artifacts` has a single up-to-date target.
    with open(os.path.join(outdir, "MANIFEST"), "w") as f:
        f.write("\n".join(f"{n}.hlo.txt" for n in names) + "\n")


if __name__ == "__main__":
    main()
