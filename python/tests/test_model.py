"""L2 correctness: golden app models — shapes, dtypes, hand-checked
semantics, and AOT lowering round-trips to HLO text."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model
from compile.aot import lower_model


def test_gaussian_flat_field():
    # On a constant image the (normalized) blur is the identity in steady
    # state: sum(kernel) = 16, >> 4.
    x = jnp.full((4096,), 32, jnp.int32)
    y = np.asarray(model.gaussian(x))
    assert (y[200:] == 32).all()
    # Warmup region is partial (zero-filled history).
    assert y[0] == 32 * 1 // 16


def test_unsharp_flat_field_is_identity():
    x = jnp.full((4096,), 50, jnp.int32)
    y = np.asarray(model.unsharp(x))
    assert (y[300:] == 50).all()


def test_camera_gamma_piecewise():
    # Values that land below/above the knee take different branches.
    x = jnp.zeros((4096,), jnp.int32)
    y = np.asarray(model.camera(x))
    assert y.shape == (4096,)
    # Zero input -> black level clamp -> zero -> lo branch (0*2 = 0).
    assert (y == 0).all()


def test_harris_shape_and_dtype():
    x = jnp.arange(4096, dtype=jnp.int32) % 23
    y = model.harris(x)
    assert y.shape == (4096,)
    assert y.dtype == jnp.int32


def test_resnet_matches_direct_accumulation():
    taps, tm, lanes, n_out = 4, 18, 2, 64
    rng = np.random.default_rng(7)
    x = rng.integers(-5, 5, size=(taps, n_out * tm), dtype=np.int32)
    y = np.asarray(model.resnet(jnp.asarray(x), lanes=lanes, taps=taps, time_mult=tm))
    w = np.asarray(model.resnet_weights(lanes, taps, tm)).reshape(lanes, taps, tm)
    for l in range(lanes):
        for o in range(0, n_out, 17):
            acc = 0
            for c in range(tm):
                for t in range(taps):
                    acc += int(x[t, o * tm + c]) * int(w[l, t, c])
            assert y[l, o] == acc, (l, o)


@pytest.mark.parametrize("name", list(model.MODELS))
def test_aot_lowers_to_hlo_text(name):
    text = lower_model(name)
    assert "HloModule" in text
    assert "ROOT" in text
    # Tuple-rooted for the Rust to_tuple1() unwrap.
    assert "tuple" in text
