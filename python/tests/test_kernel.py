"""L1 correctness: Pallas kernels vs pure-jnp/numpy oracles.

Hypothesis sweeps shapes, weights and data; `interpret=True` keeps the
kernels executable on CPU-PJRT (real-TPU lowering emits Mosaic custom
calls the CPU plugin cannot run).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.matmul import matmul_tiled
from compile.kernels.ref import matmul_ref, stream_stencil_ref, tap_weighted_sum_ref
from compile.kernels.stencil import stream_stencil, tap_weighted_sum


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 10),
    blocks=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_tap_weighted_sum_matches_ref(t, blocks, seed):
    rng = np.random.default_rng(seed)
    n = 512 * blocks
    taps = rng.integers(-50, 50, size=(t, n), dtype=np.int32)
    w = rng.integers(-8, 8, size=(t,), dtype=np.int32)
    out = tap_weighted_sum(jnp.asarray(taps), jnp.asarray(w))
    ref = tap_weighted_sum_ref(jnp.asarray(taps), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([8, 16, 24]),
    k=st.sampled_from([8, 16, 32, 72]),
    n=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_tiled_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(-9, 9, size=(m, k), dtype=np.int32)
    x = rng.integers(-9, 9, size=(k, n), dtype=np.int32)
    out = matmul_tiled(jnp.asarray(w), jnp.asarray(x))
    ref = matmul_ref(jnp.asarray(w), jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_stream_stencil_matches_numpy_ref(seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 64, size=(4096,), dtype=np.int32)
    kernel = ((1, 2, 1), (2, 4, 2), (1, 2, 1))
    out = stream_stencil(jnp.asarray(x), 64, kernel)
    ref = stream_stencil_ref(x, 64, kernel)
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_tap_sum_rejects_ragged_stream():
    with pytest.raises(AssertionError):
        tap_weighted_sum(jnp.zeros((2, 100), jnp.int32), jnp.ones((2,), jnp.int32))


def test_matmul_rejects_unaligned():
    with pytest.raises(AssertionError):
        matmul_tiled(jnp.zeros((7, 8), jnp.int32), jnp.zeros((8, 16), jnp.int32))
